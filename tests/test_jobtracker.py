import numpy as np
import pytest

from repro.core import (CoaddQuery, FailureInjector, JobTracker, SpatialIndex,
                        SurveyConfig, make_survey)
from repro.core.engine import CoaddEngine

SURVEY = make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=50,
                                  height=16, width=16))
ENGINE = CoaddEngine(SURVEY, pack_capacity=16)
QUERY = CoaddQuery(band="g", ra_bounds=(37.2, 37.8), dec_bounds=(-0.6, 0.4), npix=32)
IDS = SpatialIndex.build(SURVEY).select(QUERY)


def executor(image_ids):
    res = ENGINE.run(QUERY, "sql_structured")  # noqa: F841 (warms jit caches)
    # Re-run restricted to the shard (deterministic pure function of inputs).
    ids = [i for i in image_ids]
    px = np.stack([SURVEY.images[i].pixels for i in ids])
    import jax.numpy as jnp
    from repro.core.engine import _coadd_batch, _query_vec
    from repro.core.mapper import query_grid_sky
    tab = SURVEY.meta_table()
    ints = {k: jnp.asarray(tab[k][ids]) for k in ("image_id", "run", "camcol", "band_id", "field")}
    floats = {k: jnp.asarray(tab[k][ids]) for k in ("t_obs", "ra_min", "ra_max", "dec_min", "dec_max")}
    gr, gd = query_grid_sky(QUERY)
    c, d, _ = _coadd_batch(jnp.asarray(px),
                           jnp.asarray(np.stack([SURVEY.images[i].wcs.to_vector() for i in ids])),
                           ints, floats, jnp.asarray(_query_vec(QUERY)),
                           jnp.asarray(gr), jnp.asarray(gd))
    return np.asarray(c), np.asarray(d)


def reference():
    t = JobTracker(executor, n_workers=4)
    return t.run(JobTracker.split(IDS, 4))


def test_failure_reexecution_preserves_result():
    ref_c, ref_d = reference()
    inj = FailureInjector({(0, 0): "fail", (2, 0): "fail", (2, 1): "fail"})
    t = JobTracker(executor, n_workers=4, injector=inj)
    c, d = t.run(JobTracker.split(IDS, 4))
    np.testing.assert_allclose(c, ref_c, atol=1e-4)
    np.testing.assert_array_equal(d, ref_d)
    assert any("retry" in e for e in t.events)


def test_retries_exhausted_raises():
    inj = FailureInjector({(1, a): "fail" for a in range(5)})
    t = JobTracker(executor, n_workers=2, max_attempts=3, injector=inj)
    with pytest.raises(RuntimeError, match="exhausted"):
        t.run(JobTracker.split(IDS, 3))


def test_journal_replay_skips_done_tasks():
    t = JobTracker(executor, n_workers=2)
    tasks = JobTracker.split(IDS, 3)
    t.run(tasks)
    n_events = len(t.events)
    t.run(tasks)  # restart: everything journaled
    hits = [e for e in t.events[n_events:] if "journal-hit" in e]
    assert len(hits) == len(tasks)


def test_speculative_execution_verifies_determinism():
    inj = FailureInjector({(0, 0): "slow"}, slow_s=0.01)
    t = JobTracker(executor, n_workers=2, straggler_threshold_s=0.005, injector=inj)
    c, d = t.run(JobTracker.split(IDS, 2))
    ref_c, ref_d = reference()
    np.testing.assert_allclose(c, ref_c, atol=1e-4)
    assert any("speculative" in e for e in t.events)


def test_elastic_repartition_same_result():
    ref_c, ref_d = reference()
    for n_tasks in (1, 2, 5, len(IDS)):
        t = JobTracker(executor, n_workers=3)
        c, d = t.run(JobTracker.split(IDS, n_tasks))
        np.testing.assert_allclose(c, ref_c, atol=1e-3)
        np.testing.assert_array_equal(d, ref_d)


def test_non_runtime_transient_errors_are_retried():
    """The retry-net fix: transient failures of ANY classified type (not
    just RuntimeError) consume a retry and re-execute to the same result."""
    ref_c, ref_d = reference()
    inj = FailureInjector({(0, 0): "fail_os", (1, 0): "fail_transient"})
    t = JobTracker(executor, n_workers=4, injector=inj)
    c, d = t.run(JobTracker.split(IDS, 4))
    np.testing.assert_allclose(c, ref_c, atol=1e-4)
    np.testing.assert_array_equal(d, ref_d)
    assert sum("retry" in e for e in t.events) == 2


def test_fatal_errors_escape_the_retry_net():
    """Fatal errors (ValueError here; DeterminismError in production) must
    escape immediately — re-rolling them is wrong."""
    inj = FailureInjector({(0, 0): "fail_fatal"})
    t = JobTracker(executor, n_workers=4, injector=inj)
    with pytest.raises(ValueError):
        t.run(JobTracker.split(IDS, 4))
    assert not any("retry" in e for e in t.events)
