"""Property-based suite for the robust reducers (ISSUE 10 satellite).

Four invariants pin the `reducer` robust-stacking contract:

1. **Permutation invariance** — a robust stack is a function of the sample
   *set*; shuffling the image axis changes nothing beyond float summation
   order.
2. **No-outlier identity** — when every sample sits inside the clip window,
   the clipped stack IS the mean stack, bitwise (the keep mask is all-True,
   so the very same sums run).  Stacks are kept at depth <= 9 per pixel:
   any sample of n values has max |x - mean| <= sigma*sqrt(n-1), so n <= 9
   guarantees no 3-sigma clip can fire regardless of the drawn values.
3. **Outlier rejection** — one sample displaced by a large delta from an
   otherwise-constant stack never survives: with N >= k^2 + 2 images the
   outlier's distance (sigma*sqrt(N-1)) clears the k-sigma radius, for the
   clipped mean and the two-round median alike, and the surviving depth is
   exactly N - 1.
4. **Odd-N constant-stack median exactness** — a constant stack of dyadic
   values (exact float sums => exact moments => sigma == 0) reports the
   constant exactly: binapprox degenerates to med = mu with a true-zero bin
   width, not an epsilon-wide one.

Each property is a plain ``_check_*`` helper driven two ways: a seeded
deterministic grid (always runs, keeps the properties in the tier-1 lane
even where hypothesis isn't installed) and a hypothesis `@given` search.

Plus the §11 bugfix regressions: ``reducer.normalize`` and
``CoaddResult.normalized`` must divide fractional depths exactly (a
depth-0.5 border pixel is *routine* once clip masks exist) and mask
depth == 0 exactly rather than through an epsilon clamp.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reducer
from repro.core.engine import CoaddResult, JobStats

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # the deterministic grids below still run
    HAVE_HYPOTHESIS = False

H = W = 6
ROBUST = ("clipped", "median")


def _random_stack(rng, n, lo=5.0, hi=15.0, cover=0.8):
    """(tiles, covs) for n images: uniform samples, Bernoulli coverage."""
    x = rng.uniform(lo, hi, (n, H, W)).astype(np.float32)
    c = (rng.uniform(size=(n, H, W)) < cover).astype(np.float32)
    return jnp.asarray(x * c), jnp.asarray(c)


# ----- 1. permutation invariance -----

def _check_permutation_invariance(seed):
    rng = np.random.default_rng(seed)
    tiles, covs = _random_stack(rng, 12)
    perm = rng.permutation(12)
    for red in ROBUST:
        a_c, a_d = reducer.robust_local(tiles, covs, red)
        b_c, b_d = reducer.robust_local(tiles[perm], covs[perm], red)
        np.testing.assert_allclose(a_c, b_c, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(a_d, b_d, rtol=1e-6, atol=1e-5)


# ----- 2. clipped == mean when nothing is an outlier -----

def _check_clipped_is_mean_without_outliers(seed):
    # Depth <= 8 per pixel: max deviation of any 8-sample set is
    # sigma*sqrt(7) ~ 2.65 sigma < 3 sigma, so the keep mask is all-True
    # and the clipped sums are THE mean sums — bitwise.
    rng = np.random.default_rng(seed)
    tiles, covs = _random_stack(rng, 8, cover=1.0)
    mean_c, mean_d = reducer.reduce_local(tiles, covs)
    clip_c, clip_d = reducer.robust_local(tiles, covs, "clipped")
    assert np.array_equal(np.asarray(mean_c), np.asarray(clip_c))
    assert np.array_equal(np.asarray(mean_d), np.asarray(clip_d))


# ----- 3. a single > k-sigma outlier never survives -----

def _check_outlier_rejected(base, delta, outlier_idx, n=16):
    x = np.full((n, H, W), base, np.float32)
    x[outlier_idx] += np.float32(delta)
    tiles = jnp.asarray(x)
    covs = jnp.ones((n, H, W), jnp.float32)
    for red in ROBUST:
        coadd, depth = reducer.robust_local(tiles, covs, red)
        # The outlier is gone — exactly n-1 samples survive everywhere...
        np.testing.assert_array_equal(np.asarray(depth), n - 1.0)
        # ...and what survives is the constant base stack.
        np.testing.assert_allclose(
            np.asarray(coadd), (n - 1.0) * base, rtol=2e-5
        )


# ----- 4. median of an odd-N constant stack is exact -----

def _check_median_constant_exact(value, n):
    # Dyadic values make every partial sum exact, so mu == value and
    # sigma == 0 exactly; binapprox must then report med == mu with a
    # *true* zero bin width (the inv_w clamp must not leak an epsilon
    # into the bin centers).
    assert n % 2 == 1
    tiles = jnp.full((n, H, W), value, jnp.float32)
    covs = jnp.ones((n, H, W), jnp.float32)
    coadd, depth = reducer.robust_local(tiles, covs, "median")
    np.testing.assert_array_equal(np.asarray(depth), float(n))
    out = np.asarray(reducer.normalize(coadd, depth))
    np.testing.assert_array_equal(out, np.float32(value))


# ----- seeded deterministic grids (always run) -----

SEEDS = [82, 7, 1010, 2026]
OUTLIER_GRID = [
    (10.0, 500.0, 3),
    (10.0, -400.0, 0),
    (0.25, 50.0, 9),
    (-6.0, 900.0, 15),
]
CONSTANT_GRID = [(1.25, 3), (7.5, 5), (0.375, 9), (12.0, 15)]


@pytest.mark.parametrize("seed", SEEDS)
def test_permutation_invariance(seed):
    _check_permutation_invariance(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_clipped_is_mean_without_outliers(seed):
    _check_clipped_is_mean_without_outliers(seed)


@pytest.mark.parametrize("base,delta,idx", OUTLIER_GRID)
def test_outlier_rejected(base, delta, idx):
    _check_outlier_rejected(base, delta, idx)


@pytest.mark.parametrize("value,n", CONSTANT_GRID)
def test_median_constant_exact(value, n):
    _check_median_constant_exact(value, n)


def test_unknown_reduce_rejected():
    tiles = jnp.ones((3, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="unknown reduce"):
        reducer.robust_local(tiles, tiles, "trimmed")


# ----- §11 bugfix regressions: exact depth masking -----

def test_normalize_fractional_depth_exact():
    # A depth-0.5 pixel (half-weight border sample surviving a clip) must
    # divide by exactly 0.5 — any epsilon clamp or epsilon add skews it.
    coadd = jnp.asarray([[3.0, 0.0], [1.0, 2.5]], jnp.float32)
    depth = jnp.asarray([[0.5, 0.0], [1e-7, 2.5]], jnp.float32)
    out = np.asarray(reducer.normalize(coadd, depth))
    assert out[0, 0] == np.float32(3.0) / np.float32(0.5)  # exactly 6.0
    assert out[0, 1] == 0.0                                # masked, not 0/eps
    assert out[1, 0] == np.float32(1.0) / np.float32(1e-7)  # tiny but real
    assert out[1, 1] == np.float32(1.0)


def test_result_normalized_fractional_depth_exact():
    # Same contract on the host-side result object.
    stats = JobStats("m", 0, 0, 0, 0.0, 0.0, 0.0)
    res = CoaddResult(
        coadd=np.asarray([[3.0, 7.0]], np.float32),
        depth=np.asarray([[0.5, 0.0]], np.float32),
        stats=stats,
    )
    out = res.normalized
    assert out[0, 0] == np.float32(6.0)
    assert out[0, 1] == 0.0


# ----- hypothesis-driven search over the same properties -----

if HAVE_HYPOTHESIS:
    _common = settings(
        deadline=None,
        max_examples=20,
        suppress_health_check=[HealthCheck.too_slow],
    )

    @_common
    @given(seed=st.integers(0, 2**31 - 1))
    def test_permutation_invariance_hypothesis(seed):
        _check_permutation_invariance(seed)

    @_common
    @given(seed=st.integers(0, 2**31 - 1))
    def test_clipped_is_mean_without_outliers_hypothesis(seed):
        _check_clipped_is_mean_without_outliers(seed)

    @_common
    @given(
        base=st.floats(-20.0, 20.0),
        delta=st.one_of(st.floats(50.0, 2000.0), st.floats(-2000.0, -50.0)),
        idx=st.integers(0, 15),
    )
    def test_outlier_rejected_hypothesis(base, delta, idx):
        _check_outlier_rejected(base, delta, idx)

    @_common
    @given(
        k=st.integers(-160, 160),
        n=st.integers(1, 10),
    )
    def test_median_constant_exact_hypothesis(k, n):
        _check_median_constant_exact(k / 8.0, 2 * n + 1)
