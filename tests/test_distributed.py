"""Distributed tests on 8 forced host devices — run in subprocesses so the
device-count flag never leaks into the rest of the suite."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Each test forks a fresh interpreter and re-compiles on 8 host devices —
# ~70s of the suite's wall clock; excluded from the CI fast lane.
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_distributed_coadd_matches_serial():
    out = run_py('''
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey
        sv = make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=60, height=16, width=16))
        eng = CoaddEngine(sv, pack_capacity=16)
        qs = [CoaddQuery(band="r", ra_bounds=(37.2,37.8), dec_bounds=(-0.5,0.3), npix=32)]
        mesh = jax.make_mesh((4,2), ("data","model"))
        rd = eng.run_distributed(qs, mesh)[0]
        rs = eng.run(qs[0], "sql_structured")
        assert np.abs(rd.coadd-rs.coadd).max() < 1e-2, np.abs(rd.coadd-rs.coadd).max()
        assert np.array_equal(rd.depth, rs.depth)
        mesh3 = jax.make_mesh((2,2,2), ("pod","data","model"))
        rp = eng.run_distributed(qs, mesh3, data_axes=("pod","data"))[0]
        assert np.abs(rp.coadd-rs.coadd).max() < 1e-2
        # Sparse per-shard compaction (the default above) must agree with the
        # dense masked-discard scan on a real 8-shard mesh, and scan less.
        eng_dense = CoaddEngine(sv, pack_capacity=16, sparse=False)
        rdd = eng_dense.run_distributed(qs, mesh)[0]
        assert np.abs(rd.coadd-rdd.coadd).max() < 1e-4
        assert np.array_equal(rd.depth, rdd.depth)
        assert rd.stats.packs_scanned < rdd.stats.packs_scanned, (
            rd.stats.packs_scanned, rdd.stats.packs_scanned)
        assert rd.stats.packs_touched <= 8  # shard slabs, honest flat-gate stat
        print("OK")
    ''')
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_py('''
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, numpy as np, jax.numpy as jnp
        from repro.configs.registry import reduced_config
        from repro.configs.base import ShapeConfig
        from repro.launch import specs as S
        from repro.models.model import build_model
        from repro.optim.adamw import adamw_init
        cfg = dataclasses.replace(reduced_config("qwen2-1.5b"), dtype="float32")
        mesh = jax.make_mesh((4,2), ("data","model"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        batch = {"tokens": jnp.zeros((8,16),jnp.int32)+3, "labels": jnp.ones((8,16),jnp.int32)}
        step = S.make_train_step(model)
        # single device
        p1,o1,m1 = jax.jit(step)(params,opt,batch)
        # sharded
        from repro.distributed import sharding as R
        ps = R.named_shardings(R.param_pspecs(jax.eval_shape(lambda: params), mesh), mesh)
        with mesh:
            p2,o2,m2 = jax.jit(step, in_shardings=(ps,None,None), out_shardings=(ps,None,None))(params,opt,batch)
        # Sharded execution reassociates the fp32 gradient reductions (psum
        # tree order != single-device sum order), and AdamW's 1/(sqrt(v)+eps)
        # amplifies that; observed drift is ~2.3e-5 on O(1) weights, so admit
        # reassociation-level error rather than bitwise equality.
        d = max(float(jnp.abs(a-b).max()) for a,b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 1e-4, d
        assert abs(float(m1["loss"])-float(m2["loss"])) < 1e-5
        print("OK")
    ''')
    assert "OK" in out


def test_train_crash_resume_bitwise_equal(tmp_path):
    base = f'''
        import sys
        sys.argv = ["train"]
        from repro.launch.train import main
    '''
    run_dir_a = str(tmp_path / "a")
    run_dir_b = str(tmp_path / "b")
    common = ("--arch qwen2-1.5b --reduced --steps 12 --global-batch 4 "
              "--seq-len 32 --vocab 128 --ckpt-every 4 --log-every 100")
    # uninterrupted
    run_py(f'''
        from repro.launch.train import main
        main("{common} --run-dir {run_dir_a}".split())
    ''')
    # crash at step 6, then resume
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(f'''
            from repro.launch.train import main
            main("{common} --run-dir {run_dir_b} --crash-at-step 6".split())
        ''')],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")), timeout=420)
    assert r.returncode != 0  # the drill crashed
    run_py(f'''
        from repro.launch.train import main
        main("{common} --run-dir {run_dir_b}".split())
    ''')
    a = json.load(open(os.path.join(run_dir_a, "result.json")))
    b = json.load(open(os.path.join(run_dir_b, "result.json")))
    assert a["final_loss"] == pytest.approx(b["final_loss"], abs=1e-6), (
        a["final_loss"], b["final_loss"])
