"""Brick-tessellated materialized coadds (DESIGN.md §9).

Three contracts under test:

* **Tessellation**: the brick grid covers any footprint exactly — every
  point lands in one and only one nominal cell, and every brick's pixel
  grid is bitwise a tile of the one global lattice (property-style over
  random footprints).
* **Parity**: a brick-aligned query served by mosaicking cached bricks is
  *bitwise* identical to the fresh lattice-window scan, across all six
  methods, the Pallas mosaic kernel, the host-spill path, and partially
  quarantined bricks (which propagate ``partial=True`` honestly).
* **Fault domain**: `materialize_bricks` is journaled — a mid-job kill
  leaves finished bricks in the store and the in-flight brick's window
  journal intact, and the re-issued job skips the former and resumes the
  latter.
"""
import numpy as np
import pytest

from repro.core import (
    BrickGrid,
    ChaosInjector,
    CoaddEngine,
    CoaddQuery,
    FaultSchedule,
    METHODS,
    PoisonSpec,
    QueryKilled,
    SurveyConfig,
    make_survey,
)
from repro.core import reducer
from repro.kernels.warp import ops as warp_ops


@pytest.fixture(scope="module")
def survey():
    return make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=60,
                                    height=16, width=16))


def _engine(survey, **kw):
    kw.setdefault("pack_capacity", 8)
    kw.setdefault("brick_deg", 0.5)
    kw.setdefault("brick_npix", 16)
    return CoaddEngine(survey, **kw)


def _streaming(survey, injector=None, **kw):
    """A 4x-oversubscribed streaming brick engine (test_faults idiom)."""
    probe = _engine(survey)
    ds = probe.exec_dataset("structured")[0]
    budget = max(ds.chunk_nbytes(0, ds.n_packs) // 4, 1)
    return _engine(survey, device_budget_bytes=budget, stream_chunk_packs=1,
                   fault_backoff_s=1e-4, fault_injector=injector, **kw)


def _region(grid, r0, r1, c0, c1):
    """A (ra_bounds, dec_bounds) region intersecting exactly these cells."""
    eps = 1e-9
    return (
        (grid.ra0 + c0 * grid.brick_deg + eps,
         grid.ra0 + c1 * grid.brick_deg - eps),
        (grid.dec0 + r0 * grid.brick_deg + eps,
         grid.dec0 + r1 * grid.brick_deg - eps),
    )


# ----- tessellation: exact cover of the footprint --------------------------

def test_tessellation_covers_random_footprints_exactly():
    rng = np.random.default_rng(9)
    for _ in range(20):
        ra0 = float(rng.uniform(0, 300))
        dec0 = float(rng.uniform(-10, 10))
        ra_span = float(rng.uniform(0.3, 4.0))
        dec_span = float(rng.uniform(0.3, 4.0))
        bd = float(rng.choice([0.25, 0.5, 1.0]))
        grid = BrickGrid.for_bounds(ra0, dec0, ra_span, dec_span,
                                    brick_deg=bd, brick_npix=8)
        # Coverage: the lattice extends at least to the footprint edge.
        assert grid.n_cols * bd >= ra_span - 1e-9
        assert grid.n_rows * bd >= dec_span - 1e-9
        # No gaps, no double cover: every sample point inside the footprint
        # locates to exactly one cell, and that cell's nominal (half-open)
        # box contains it.
        for _ in range(50):
            ra = ra0 + float(rng.uniform(0, ra_span))
            dec = dec0 + float(rng.uniform(0, dec_span))
            cell = grid.locate(ra, dec)
            assert cell is not None
            r, c = cell
            lo_ra, hi_ra, lo_dec, hi_dec = grid.nominal_box(r, c)
            assert lo_ra <= ra < hi_ra and lo_dec <= dec < hi_dec
        # Adjacent nominal boxes tile with shared edges (no slivers).
        if grid.n_cols > 1:
            assert grid.nominal_box(0, 0)[1] == grid.nominal_box(0, 1)[0]
        if grid.n_rows > 1:
            assert grid.nominal_box(0, 0)[3] == grid.nominal_box(1, 0)[2]


def test_brick_grids_are_bitwise_tiles_of_the_lattice():
    grid = BrickGrid.for_bounds(37.0, -1.0, 1.5, 1.0,
                                brick_deg=0.5, brick_npix=8)
    b = grid.brick_npix
    full_ra, full_dec = grid.window_sky(0, grid.n_rows, 0, grid.n_cols)
    for r in range(grid.n_rows):
        for c in range(grid.n_cols):
            tra, tdec = grid.brick_sky(r, c)
            np.testing.assert_array_equal(
                tra, full_ra[r * b:(r + 1) * b, c * b:(c + 1) * b])
            np.testing.assert_array_equal(
                tdec, full_dec[r * b:(r + 1) * b, c * b:(c + 1) * b])


def test_window_query_roundtrips_through_decompose():
    grid = BrickGrid.for_bounds(37.0, -1.0, 1.5, 1.0,
                                brick_deg=0.5, brick_npix=8)
    cover = grid.decompose(grid.window_query(0, 2, 1, 3, "g"))
    assert cover is not None
    assert (cover.r0, cover.r1, cover.c0, cover.c1) == (0, 2, 1, 3)
    assert cover.bricks == [(0, 1), (0, 2), (1, 1), (1, 2)]
    # Unaligned shapes refuse to decompose.
    assert grid.decompose(CoaddQuery(band="g", ra_bounds=(37.1, 37.9),
                                     dec_bounds=(-0.9, -0.1), npix=16)) is None
    timed = grid.window_query(0, 1, 0, 1, "g")
    timed = CoaddQuery(band="g", ra_bounds=timed.ra_bounds,
                       dec_bounds=timed.dec_bounds, npix=timed.npix,
                       time_bounds=(0.0, 1.0))
    assert grid.decompose(timed) is None


# ----- parity: mosaic == fresh, bitwise, all six methods -------------------

@pytest.mark.parametrize("method", METHODS)
def test_mosaic_matches_fresh_bitwise(survey, method):
    eng = _engine(survey)
    wq = eng.brick_grid.window_query(1, 3, 0, 2, "r")
    fresh = eng.run_window(wq, method)
    cold = eng.run(wq, method, use_bricks=True)
    assert cold.stats.bricks_missed == 4 and cold.stats.bricks_hit == 0
    assert cold.stats.residual_packs_scanned > 0
    np.testing.assert_array_equal(cold.coadd, fresh.coadd)
    np.testing.assert_array_equal(cold.depth, fresh.depth)
    warm = eng.run(wq, method, use_bricks=True)
    assert warm.stats.bricks_hit == 4 and warm.stats.bricks_missed == 0
    assert warm.stats.residual_packs_scanned == 0
    assert warm.stats.dispatches == 1  # just the mosaic merge
    np.testing.assert_array_equal(warm.coadd, fresh.coadd)
    np.testing.assert_array_equal(warm.depth, fresh.depth)


def test_pallas_mosaic_kernel_matches_xla():
    rng = np.random.default_rng(3)
    b, npix = 8, 16
    offsets = np.array([[0, 0], [0, 8], [8, 0], [8, 8]], np.int32)
    tiles = rng.normal(size=(4, b, b)).astype(np.float32)
    covs = rng.uniform(size=(4, b, b)).astype(np.float32)
    xc, xd = reducer.mosaic_tiles(tiles, covs, offsets, npix)
    kc, kd = warp_ops.mosaic_bricks(tiles, covs, offsets, npix)
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(xc))
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(xd))


def test_kernel_engine_mosaic_parity(survey):
    eng = _engine(survey, use_kernel=True)
    wq = eng.brick_grid.window_query(1, 3, 0, 2, "r")
    fresh = eng.run_window(wq, "sql_structured")
    eng.run(wq, "sql_structured", use_bricks=True)  # materialize
    warm = eng.run(wq, "sql_structured", use_bricks=True)
    assert warm.stats.bricks_hit == 4
    np.testing.assert_array_equal(warm.coadd, fresh.coadd)
    np.testing.assert_array_equal(warm.depth, fresh.depth)


def test_spilled_bricks_serve_from_host_tier(survey):
    eng = _engine(survey)
    wq = eng.brick_grid.window_query(1, 3, 0, 2, "r")
    fresh = eng.run_window(wq, "sql_structured")
    eng.materialize_bricks(bands=("r",))
    dropped = eng.brick_store.drop_device()
    assert dropped >= 4
    r = eng.run(wq, "sql_structured", use_bricks=True)
    # Every tile re-uploaded from the host copy: no recompute, no scan.
    assert r.stats.bricks_spilled == 4
    assert r.stats.bricks_hit == 0 and r.stats.bricks_missed == 0
    assert r.stats.residual_packs_scanned == 0
    assert eng.brick_store.spill_loads >= 4
    np.testing.assert_array_equal(r.coadd, fresh.coadd)
    np.testing.assert_array_equal(r.depth, fresh.depth)


def test_unaligned_query_falls_back_transparently(survey):
    eng = _engine(survey)
    q = CoaddQuery(band="r", ra_bounds=(37.0, 37.3),
                   dec_bounds=(-0.5, -0.2), npix=48)
    plain = eng.run(q, "sql_structured")
    fb = eng.run(q, "sql_structured", use_bricks=True)
    assert fb.stats.bricks_hit == 0 and fb.stats.bricks_missed == 0
    np.testing.assert_array_equal(fb.coadd, plain.coadd)
    np.testing.assert_array_equal(fb.depth, plain.depth)


def test_materialized_bricks_key_on_psf_state(survey):
    eng = _engine(survey)
    wq = eng.brick_grid.window_query(1, 3, 0, 2, "r")
    eng.run(wq, "sql_structured", use_bricks=True)
    # Retune: same store, different psf state — every key must miss.
    eng.match_psf_sigma = 2.0
    wq2 = eng.brick_grid.window_query(1, 3, 0, 2, "r")
    r = eng.run(wq2, "sql_structured", use_bricks=True)
    assert r.stats.bricks_missed == 4 and r.stats.bricks_hit == 0


# ----- partial bricks propagate --------------------------------------------

def test_partial_brick_propagates_into_mosaic(survey):
    probe = _streaming(survey)
    plan = probe._brick_plan("r", 1, 0, "sql_structured")
    gated = np.nonzero(probe._exec_gate(plan).any(axis=1))[0]
    assert len(gated) > 0
    bad = int(gated[0])
    inj = ChaosInjector(FaultSchedule(
        poison=(PoisonSpec(pack=bad, mode="nan", count=None),)  # persistent
    ))
    eng = _streaming(survey, injector=inj, on_fault="quarantine")
    rep = eng.materialize_bricks(bands=("r",),
                                 region=_region(eng.brick_grid, 1, 3, 0, 2))
    assert rep.completed == 4 and rep.partial_bricks >= 1
    wq = eng.brick_grid.window_query(1, 3, 0, 2, "r")
    r = eng.run(wq, "sql_structured", use_bricks=True)
    assert r.stats.bricks_hit == 4
    assert r.stats.partial
    assert bad in r.stats.uncovered_packs


# ----- kill-and-resume of materialization ----------------------------------

def test_materialize_survives_kill_and_resume(survey):
    region_args = (1, 3, 0, 2)
    # Aim the kill mid-job: brick 1's second window, so brick 0 finishes
    # and brick 1 leaves a non-empty window journal behind.
    probe = _streaming(survey)
    cells = probe.brick_grid.bricks(_region(probe.brick_grid, *region_args))
    assert len(cells) == 4

    def n_windows(engine, cell):
        plan = engine._brick_plan("r", cell[0], cell[1], "sql_structured")
        exec_ds, _ = engine.exec_dataset(plan.layout)
        gate = engine._exec_gate(plan)
        return len(engine._stream_windows(exec_ds, gate.any(axis=1)))
    assert n_windows(probe, cells[1]) >= 2
    kill_after = n_windows(probe, cells[0]) + 1

    inj = ChaosInjector(FaultSchedule(kill_after_windows=kill_after))
    eng = _streaming(survey, injector=inj)
    with pytest.raises(QueryKilled):
        eng.materialize_bricks(bands=("r",),
                               region=_region(eng.brick_grid, *region_args))
    done = len(eng.brick_store)
    assert 0 < done < len(cells)          # finished bricks persisted
    assert len(eng._journals) == 1        # in-flight brick's journal kept

    # Re-issue: finished bricks skip, the killed one resumes its journal.
    rep = eng.materialize_bricks(bands=("r",),
                                 region=_region(eng.brick_grid, *region_args))
    assert rep.skipped == done
    assert rep.completed == len(cells) - done
    assert any(t.resumed_windows > 0 for t in rep.tasks)
    assert len(eng.brick_store) == len(cells)

    # The resumed store serves bitwise-correct mosaics.
    clean = _streaming(survey)
    wq = eng.brick_grid.window_query(*region_args, "r")
    fresh = clean.run_window(wq, "sql_structured")
    warm = eng.run(wq, "sql_structured", use_bricks=True)
    assert warm.stats.bricks_hit == 4
    np.testing.assert_array_equal(warm.coadd, fresh.coadd)
    np.testing.assert_array_equal(warm.depth, fresh.depth)
