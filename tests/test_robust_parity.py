"""Golden parity for the robust reducers (ISSUE 10 satellite).

The reference is *plain numpy*, float32, mirroring `repro.core.reducer`
operation-for-operation, fed by per-image stacks the engine itself
produces: a single-image time-bounded query returns exactly the warped
tile + coverage that image contributes to any stack, so composing those
through the numpy reference gives the answer every robust path must
reproduce — eager, streaming (4x oversubscribed), brick-served, XLA and
Pallas, across all six access methods.

Depth comparisons are **bitwise**: depth is a sum of small coverage
weights, so any disagreement means a clip *decision* flipped, not a
rounding difference.  Coadd comparisons use the same 2e-3 tolerance the
existing cross-method mean-parity test needs — the engine accumulates
per-image contributions in pack-layout order, the reference in survey
order, and float32 summation order is the one thing the contract does
not pin.

Plus the two-pass contract itself: the fused single-dispatch composition
(`reducer.robust_local`) must be bitwise identical to running the
moments / histogram / clip passes as separate jitted programs with the
between-pass values as plain operands — that equivalence is what makes
the streaming multi-pass schedule legal.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    METHODS,
    CoaddEngine,
    CoaddQuery,
    SurveyConfig,
    make_survey,
)
from repro.core import reducer

ROBUST = ("clipped", "median")
CLIP_K = 3.0
NBINS = 16

QUERY = CoaddQuery(band="r", ra_bounds=(37.3, 37.9), dec_bounds=(-0.5, 0.3),
                   npix=32)


@pytest.fixture(scope="module")
def survey():
    return make_survey(SurveyConfig(n_runs=3, n_fields=4, n_sources=80,
                                    height=16, width=16))


@pytest.fixture(scope="module")
def engine(survey):
    return CoaddEngine(survey, pack_capacity=8)


@pytest.fixture(scope="module")
def per_image(engine):
    """(tiles, covs) — per-sample warped (npix, npix) slices via
    single-epoch time-bounded queries.  A ``t_obs`` selects one (run,
    field) strip whose camcol frames tile without overlap (depth <= 1
    everywhere), so each slice holds each pixel's contribution from at
    most ONE image — exactly the float32 samples the robust scans see;
    the numpy reference differs from the engine only in summation
    order."""
    tiles, covs = [], []
    times = sorted({float(im.t_obs) for im in engine.survey.images
                    if im.band == QUERY.band})
    for t in times:
        q = dataclasses.replace(QUERY, time_bounds=(t, t))
        r = engine.run(q, "sql_structured")
        if r.depth.max() > 0:
            assert r.depth.max() <= 1.0  # no overlap within one slice
            tiles.append(np.asarray(r.coadd, np.float32))
            covs.append(np.asarray(r.depth, np.float32))
    assert len(tiles) >= 3  # a stack, not a single image
    return np.stack(tiles), np.stack(covs)


def _np_robust(tiles, covs, reduce, clip_k=CLIP_K, nbins=NBINS):
    """Plain-numpy float32 mirror of reducer.robust_local."""
    f32 = np.float32
    t, c = tiles.astype(f32), covs.astype(f32)
    cov = c > 0
    x = np.where(cov, t / np.where(cov, c, f32(1.0)), f32(0.0)).astype(f32)
    s0, s1, s2 = c.sum(0), t.sum(0), (x * t).sum(0)
    pos = s0 > 0
    safe = np.where(pos, s0, f32(1.0))
    mu = np.where(pos, s1 / safe, f32(0.0))
    var = np.maximum(np.where(pos, s2 / safe, f32(0.0)) - mu * mu, f32(0.0))
    sigma = np.sqrt(var)
    if reduce == "median":
        lo = mu - sigma
        w = f32(2.0) * sigma / f32(nbins)
        inv_w = f32(1.0) / np.maximum(w, f32(1e-30))
        b = np.clip(np.floor((x - lo) * inv_w), 0, nbins - 1).astype(np.int32)
        hist = np.zeros((nbins,) + s0.shape, f32)
        for j in range(nbins):
            hist[j] = ((b == j) * np.where(cov, c, f32(0.0))).sum(0)
        csum = np.cumsum(hist, axis=0)
        j = np.argmax(csum >= f32(0.5) * s0, axis=0).astype(f32)
        center = lo + (j + f32(0.5)) * w
    else:
        center = mu
    thresh = f32(clip_k) * sigma + f32(1e-3) * np.abs(center) + f32(1e-12)
    # Division-free clip test, mirroring reducer.clip_local exactly.
    keep = cov & (np.abs(t - c * center) <= c * thresh)
    return (np.where(keep, t, f32(0.0)).sum(0),
            np.where(keep, c, f32(0.0)).sum(0))


@pytest.fixture(scope="module")
def golden(per_image):
    tiles, covs = per_image
    return {red: _np_robust(tiles, covs, red) for red in ROBUST}


# ----- every access method, XLA eager path -----

@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("red", ROBUST)
def test_methods_match_golden(engine, golden, method, red):
    ref_c, ref_d = golden[red]
    r = engine.run(QUERY, method, reduce=red)
    assert r.stats.reduce == red
    np.testing.assert_array_equal(r.depth, ref_d)     # clip decisions
    np.testing.assert_allclose(r.coadd, ref_c, atol=2e-3)


# ----- streaming multi-pass at 4x oversubscription -----

@pytest.mark.parametrize("red", ROBUST)
def test_streaming_matches_golden(survey, golden, red):
    probe = CoaddEngine(survey, pack_capacity=8)
    ds = probe.exec_dataset("structured")[0]
    budget = max(ds.chunk_nbytes(0, ds.n_packs) // 4, 1)
    eng = CoaddEngine(survey, pack_capacity=8, device_budget_bytes=budget,
                      stream_chunk_packs=2)
    ref_c, ref_d = golden[red]
    r = eng.run(QUERY, "sql_structured", reduce=red)
    assert r.stats.windows > 1                         # actually streamed
    assert r.stats.reduce == red
    assert r.stats.reduce_passes == (3 if red == "median" else 2)
    np.testing.assert_array_equal(r.depth, ref_d)
    np.testing.assert_allclose(r.coadd, ref_c, atol=2e-3)


# ----- brick-served template path -----

@pytest.mark.parametrize("red", ROBUST)
def test_bricks_match_golden(engine, golden, red):
    ref_c, ref_d = golden[red]
    r = engine.run(QUERY, "sql_structured", use_bricks=True, reduce=red)
    assert r.stats.reduce == red
    np.testing.assert_array_equal(r.depth, ref_d)
    np.testing.assert_allclose(r.coadd, ref_c, atol=2e-3)


# ----- Pallas reduction kernels vs the XLA scan -----

@pytest.mark.parametrize("red", ROBUST)
def test_pallas_matches_xla(survey, engine, red):
    kern = CoaddEngine(survey, pack_capacity=8, use_kernel=True,
                       kernel_interpret=True)
    a = engine.run(QUERY, "sql_structured", reduce=red)
    b = kern.run(QUERY, "sql_structured", reduce=red)
    np.testing.assert_array_equal(a.depth, b.depth)
    np.testing.assert_allclose(a.coadd, b.coadd, atol=1e-4)


# ----- run_batch carries the estimator through -----

def test_run_batch_matches_single(engine, golden):
    queries = [QUERY, dataclasses.replace(QUERY, npix=32, band="r")]
    for red in ROBUST:
        ref_c, ref_d = golden[red]
        rs = engine.run_batch(queries, "sql_structured", reduce=red)
        for r in rs:
            assert r.stats.reduce == red
            np.testing.assert_array_equal(r.depth, ref_d)
            np.testing.assert_allclose(r.coadd, ref_c, atol=2e-3)


# ----- mean stays mean -----

def test_mean_unchanged_by_robust_plumbing(engine, per_image):
    tiles, covs = per_image
    r = engine.run(QUERY, "sql_structured")
    assert r.stats.reduce == "mean"
    assert r.stats.reduce_passes == 1
    np.testing.assert_array_equal(r.depth, covs.sum(0))
    np.testing.assert_allclose(r.coadd, tiles.sum(0), atol=2e-3)


# ----- two-pass == single-pass, bitwise, on one in-memory stack -----

@pytest.mark.parametrize("red", ROBUST)
def test_two_pass_equals_fused(red):
    rng = np.random.default_rng(11)
    tiles = jnp.asarray(rng.uniform(2, 9, (14, 8, 8)).astype(np.float32))
    covs = jnp.asarray(
        (rng.uniform(size=(14, 8, 8)) < 0.85).astype(np.float32))
    tiles = tiles * covs

    fused_c, fused_d = jax.jit(
        lambda t, c: reducer.robust_local(t, c, red, CLIP_K, NBINS)
    )(tiles, covs)

    # The streaming schedule: each pass its own program, between-pass
    # values crossing as plain arrays.  Must be bitwise — this is the
    # equivalence that lets a kill land between passes.
    s0, s1, s2 = jax.jit(reducer.moments_local)(tiles, covs)
    if red == "median":
        lo, w, inv_w = jax.jit(
            lambda a, b, c: reducer.hist_bounds(a, b, c, NBINS)
        )(s0, s1, s2)
        hist = jax.jit(
            lambda t, c, lo, iw: reducer.hist_local(t, c, lo, iw, NBINS)
        )(tiles, covs, lo, inv_w)
        center = jax.jit(reducer.hist_median)(hist, s0, lo, w)
        _, sigma = jax.jit(reducer.clip_stats)(s0, s1, s2)
    else:
        center, sigma = jax.jit(reducer.clip_stats)(s0, s1, s2)
    thresh = jax.jit(
        lambda c, s: reducer.clip_threshold(c, s, CLIP_K)
    )(center, sigma)
    pass_c, pass_d = jax.jit(reducer.clip_local)(tiles, covs, center, thresh)

    np.testing.assert_array_equal(np.asarray(fused_c), np.asarray(pass_c))
    np.testing.assert_array_equal(np.asarray(fused_d), np.asarray(pass_d))
