"""Sparse execution (DESIGN.md §5): parity, budgets, reblocking, stats.

The sparse path — budget-bucketed pack gather + compacted scan, plus
pack-major reblocking of the per-file layout — must be numerically
identical to the dense masked-discard scan for every method, kernel on or
off, single or batched, and its accounting (`packs_gated`/`packs_scanned`/
`scan_budget`) must tell the truth about how much work was skipped.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CoaddEngine,
    CoaddQuery,
    METHODS,
    SurveyConfig,
    make_survey,
    scan_budget,
    sparse_pack_index,
)
from repro.core.engine import _coadd_batch, _query_vec
from repro.core.mapper import query_grid_sky
from repro.core.plan import CoaddPlan, compact_gate, compact_gates, union_sparse_index


@pytest.fixture(scope="module")
def survey():
    return make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=60,
                                    height=16, width=16))


QUERY = CoaddQuery(band="r", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3),
                   npix=32)
QUERY2 = CoaddQuery(band="r", ra_bounds=(37.3, 37.7), dec_bounds=(-0.4, 0.2),
                    npix=32)


def _engines(survey, use_kernel=False):
    mk = lambda sparse: CoaddEngine(  # noqa: E731
        survey, pack_capacity=8, use_kernel=use_kernel, sparse=sparse
    )
    return mk(True), mk(False)


# ----- planner machinery ---------------------------------------------------

def test_scan_budget_buckets():
    assert scan_budget(0, 100) == 1      # empty gate still scans one slot row
    assert scan_budget(1, 100) == 1
    assert scan_budget(3, 100) == 4
    assert scan_budget(4, 100) == 4      # exact bucket boundary
    assert scan_budget(5, 100) == 8      # one past the boundary
    assert scan_budget(64, 100) == 64
    assert scan_budget(65, 100) == 100   # capped at the layout
    assert scan_budget(7, 4) == 4
    with pytest.raises(ValueError):
        scan_budget(1, 0)


def test_sparse_pack_index_and_compaction():
    gate = np.zeros((10, 3), bool)
    gate[2, 1] = gate[7, 0] = gate[7, 2] = True
    sp = sparse_pack_index(gate)
    assert sp.n_gated == 2 and sp.budget == 2
    assert list(sp.pack_idx) == [2, 7]
    g = compact_gate(gate, sp)
    assert g.shape == (2, 3) and g.sum() == gate.sum()
    # Padding rows must be masked False even though they duplicate pack 0.
    gate5 = np.zeros((10, 3), bool)
    gate5[[0, 1, 2, 3, 4], 0] = True     # 5 gated -> budget 8, 3 pad rows
    sp5 = sparse_pack_index(gate5)
    assert sp5.budget == 8 and sp5.n_gated == 5
    g5 = compact_gate(gate5, sp5)
    assert g5[5:].sum() == 0 and g5.sum() == 5
    # Union across a batch covers every query's packs.
    gates = np.stack([gate, gate5])
    spu = union_sparse_index(gates)
    assert spu.n_gated == 6              # packs {0,1,2,3,4,7}
    gc = compact_gates(gates, spu)
    assert gc.shape[0] == 2 and gc[0].sum() == 3 and gc[1].sum() == 5


# ----- engine parity: sparse vs dense --------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True], ids=["xla", "kernel"])
@pytest.mark.parametrize("method", [m for m in METHODS])
def test_sparse_matches_dense(survey, method, use_kernel):
    """Sparse execution is numerically identical to the dense scan."""
    eng_s, eng_d = _engines(survey, use_kernel=use_kernel)
    rs = eng_s.run(QUERY, method)
    rd = eng_d.run(QUERY, method)
    assert rd.depth.max() > 0            # non-trivial query
    # Reblocking + gather reorder the accumulation; everything else is the
    # same program, so only reassociation-level drift is allowed.
    np.testing.assert_allclose(rs.coadd, rd.coadd, atol=5e-2, rtol=1e-3)
    np.testing.assert_array_equal(rs.depth, rd.depth)
    assert rs.stats.files_considered == rd.stats.files_considered
    assert rs.stats.files_contributing == rd.stats.files_contributing
    assert rs.stats.dispatches == 1
    # The accounting must reflect the skip: never more scanned than dense.
    assert rs.stats.packs_scanned <= rd.stats.packs_scanned
    assert rs.stats.packs_gated <= rs.stats.packs_scanned == rs.stats.scan_budget


@pytest.mark.parametrize("use_kernel", [False, True], ids=["xla", "kernel"])
@pytest.mark.parametrize("method", [m for m in METHODS])
def test_sparse_batch_matches_singles(survey, method, use_kernel):
    """Union-compacted batches reproduce per-query sparse runs exactly."""
    eng_s, _ = _engines(survey, use_kernel=use_kernel)
    singles = [eng_s.run(QUERY, method), eng_s.run(QUERY2, method)]
    before = eng_s.dispatch_count
    batch = eng_s.run_batch([QUERY, QUERY2], method)
    assert eng_s.dispatch_count - before == 1    # still one dispatch per batch
    for s, b in zip(singles, batch):
        np.testing.assert_allclose(b.coadd, s.coadd, atol=1e-3, rtol=1e-4)
        np.testing.assert_array_equal(b.depth, s.depth)
        assert b.stats.files_considered == s.stats.files_considered
        assert b.stats.files_contributing == s.stats.files_contributing
        assert b.stats.packs_gated == s.stats.packs_gated


def test_empty_gate_zero_coadd_no_nans(survey):
    """A gate opening nothing yields exact zeros (and no zero-length scan)."""
    eng_s, _ = _engines(survey)
    far = CoaddQuery(band="r", ra_bounds=(200.0, 201.0),
                     dec_bounds=(50.0, 51.0), npix=32)
    before = eng_s.dispatch_count
    r = eng_s.run(far, "sql_structured")
    assert eng_s.dispatch_count - before == 1
    assert np.all(r.coadd == 0) and np.all(r.depth == 0)
    assert not np.isnan(r.normalized).any()
    assert r.stats.files_considered == 0 and r.stats.files_contributing == 0
    assert r.stats.packs_gated == 0 and r.stats.scan_budget == 1


def test_budget_bucket_boundary_through_engine(survey):
    """Gates straddling a bucket edge (4 vs 5 gated) both execute correctly."""
    eng_s, eng_d = _engines(survey)
    layout = "structured"
    ds = eng_s.dataset(layout)
    for n_packs_gated in (4, 5):         # budgets 4 and 8
        gate = np.zeros_like(ds.valid)
        gate[:n_packs_gated] = ds.valid[:n_packs_gated]
        plan = CoaddPlan("sql_structured", layout, gate, _query_vec(QUERY),
                         QUERY, 0.0)
        rs = eng_s.execute(plan)
        rd = eng_d.execute(plan)
        np.testing.assert_allclose(rs.coadd, rd.coadd, atol=5e-2, rtol=1e-3)
        np.testing.assert_array_equal(rs.depth, rd.depth)
        assert rs.stats.scan_budget == scan_budget(n_packs_gated, ds.n_packs)
        assert rs.stats.packs_gated == n_packs_gated


# ----- pack-major reblocking ----------------------------------------------

def test_reblock_remap_roundtrip(survey):
    """Reblocked dataset holds the same images; gate remap preserves them."""
    eng = CoaddEngine(survey, pack_capacity=8, sparse=True)
    ds = eng.dataset("per_file")
    rb, remap = eng.exec_dataset("per_file")
    assert ds.capacity == 1 and rb.capacity == 8
    assert rb.n_packs == int(np.ceil(ds.n_images / 8))
    assert rb.n_images == ds.n_images
    assert set(rb.index) == set(ds.index)
    # Every image's pixels land intact at its remapped slot.
    for img_id in list(ds.index)[:20]:
        p, s = ds.index[img_id]
        np.testing.assert_array_equal(
            rb.pixels[remap.rb_pack[p, s], remap.rb_slot[p, s]],
            ds.pixels[p, s])
    # A gate over a subset of files remaps to the same number of slots.
    gate = ds.valid.copy()
    gate[::3] = False
    assert remap.apply(gate).sum() == gate.sum()


def test_reblocked_per_file_matches_seed_loop(survey):
    """raw_fits* through the reblocked sparse engine == seed per-file loop."""
    eng = CoaddEngine(survey, pack_capacity=8, sparse=True)
    ds = eng.dataset("per_file")
    for method in ("raw_fits", "raw_fits_prefiltered"):
        got = eng.run(QUERY, method)
        # Seed reference: one _coadd_batch dispatch per gated file.
        plan = eng.plan(QUERY, method)
        pack_ids = np.nonzero(plan.gate.any(axis=1))[0]
        grid_ra, grid_dec = map(jnp.asarray, query_grid_sky(QUERY))
        qvec = jnp.asarray(_query_vec(QUERY))
        coadd = np.zeros((QUERY.npix, QUERY.npix), np.float32)
        depth = np.zeros((QUERY.npix, QUERY.npix), np.float32)
        contrib = 0
        for p in pack_ids:
            ints = {k: jnp.asarray(v[p]) for k, v in ds.ints.items()}
            floats = {k: jnp.asarray(v[p]) for k, v in ds.floats.items()}
            c, d, n = _coadd_batch(
                jnp.asarray(ds.pixels[p]), jnp.asarray(ds.wcs[p]), ints,
                floats, qvec, grid_ra, grid_dec)
            coadd += np.asarray(c)
            depth += np.asarray(d)
            contrib += int(n)
        assert depth.max() > 0
        np.testing.assert_allclose(got.coadd, coadd, atol=5e-2, rtol=1e-3)
        np.testing.assert_array_equal(got.depth, depth)
        assert got.stats.files_contributing == contrib
        assert got.stats.files_considered == len(pack_ids)
        # The scan must be over super-packs, not 1-image files.
        assert got.stats.packs_scanned <= eng.exec_dataset("per_file")[0].n_packs
        assert got.stats.packs_scanned < len(pack_ids) or len(pack_ids) <= 8


def test_sparse_no_reupload_across_queries(survey, monkeypatch):
    """Sparse queries reuse the resident reblocked layout: 0 re-uploads."""
    from repro.core.seqfile import PackedDataset

    eng = CoaddEngine(survey, pack_capacity=8, sparse=True)
    eng.run(QUERY, "raw_fits_prefiltered")
    uploads = eng.pack_upload_count

    def _boom(self):
        raise AssertionError("pack pixels re-uploaded on a repeat query")

    monkeypatch.setattr(PackedDataset, "to_device", _boom)
    monkeypatch.setattr(PackedDataset, "reblock", _boom)
    eng.run(QUERY2, "raw_fits_prefiltered")   # different gate, same residency
    eng.run(QUERY2, "raw_fits")
    assert eng.pack_upload_count == uploads


# ----- distributed per-shard compaction ------------------------------------

def test_shard_local_compaction_per_shard_budgets():
    """Skewed union gates get two-tier budgets: a shared static shape plus
    each shard's own bucket, so quiet shards stop over-scanning."""
    from repro.distributed.sharding import shard_local_compaction

    union = np.zeros((32,), bool)
    union[1] = True                   # shard 0: 1 gated -> bucket 1
    union[8:15] = True                # shard 1: 7 gated -> bucket 8
    union[16] = union[18] = True      # shard 2: 2 gated -> bucket 2
    #                                   shard 3: 0 gated -> bucket 1
    idx, mask, shared, budgets = shard_local_compaction(union, 4)
    assert shared == 8 and list(budgets) == [1, 8, 2, 1]
    assert idx.shape == mask.shape == (4, 8)
    # Indices are slab-local; padding masked False points at local slot 0.
    assert list(idx[0][:1]) == [1] and mask[0].sum() == 1
    assert list(idx[1][:7]) == list(range(0, 7)) and mask[1].sum() == 7
    assert list(idx[2][:2]) == [0, 2] and mask[2].sum() == 2
    assert mask[3].sum() == 0
    with pytest.raises(ValueError):
        shard_local_compaction(union, 5)  # 5 does not divide 32


def test_distributed_sparse_matches_dense(survey):
    """Per-shard local compaction reproduces the dense distributed answer,
    and the stats derive from the flat gate (shard slabs, not phantom
    structured packs)."""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng_s = CoaddEngine(survey, pack_capacity=8, sparse=True)
    eng_d = CoaddEngine(survey, pack_capacity=8, sparse=False)
    qs = [QUERY, QUERY2]
    rs = eng_s.run_distributed(qs, mesh)
    rd = eng_d.run_distributed(qs, mesh)
    n_shards = 1
    for a, b in zip(rs, rd):
        assert b.depth.max() > 0
        np.testing.assert_allclose(a.coadd, b.coadd, atol=1e-2, rtol=1e-4)
        np.testing.assert_array_equal(a.depth, b.depth)
        # Honest flat-gate stats: slabs touched bounds, budgeted scan extent.
        assert 0 < a.stats.packs_touched <= n_shards
        assert a.stats.packs_gated == a.stats.packs_touched
        assert a.stats.scan_budget <= b.stats.scan_budget
    # Scan work is attributed to the first result (like dispatches), so
    # summing packs_scanned across the job counts it exactly once — and a
    # tiny job on a resident archive must not map every image.
    assert rs[0].stats.packs_scanned == n_shards * rs[0].stats.scan_budget
    assert rs[1].stats.packs_scanned == 0
    assert rs[0].stats.packs_scanned < rd[0].stats.packs_scanned
