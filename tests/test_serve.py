"""Serving-layer drills for `core/serve.py` (DESIGN.md §10).

Each drill pins one clause of the serving contract: coalescing (K
concurrent compatible queries = ONE engine dispatch, counter-tested the
same way the run_batch tests pin re-uploads), admission QoS (cheap queries
never queue behind a convoy of monsters; typed `Overloaded` shedding at
the queue and tenant caps), the result cache (bitwise parity + hit
counters), brick routing (§9 mosaic path, popularity tallies), and the
fault domain under load (an injected transient heals inside the engine;
clients only ever see clean bitwise pixels).
"""
import asyncio

import numpy as np
import pytest

from repro.core import (
    ChaosInjector,
    CoaddEngine,
    CoaddQuery,
    CoaddService,
    FaultSchedule,
    Overloaded,
    SurveyConfig,
    make_survey,
)


@pytest.fixture(scope="module")
def survey():
    return make_survey(SurveyConfig(
        n_runs=3, n_camcols=4, n_bands=3, n_fields=6,
        height=24, width=24, n_sources=120, seed=11,
    ))


@pytest.fixture(scope="module")
def engine(survey):
    return CoaddEngine(survey, pack_capacity=16)


def cheap_q(i, npix=48):
    lo = 37.1 + 0.12 * i
    return CoaddQuery(band="r", ra_bounds=(lo, lo + 0.4),
                      dec_bounds=(-0.3, 0.3), npix=npix)


def monster_q(npix):
    return CoaddQuery(band="r", ra_bounds=(37.0, 38.5),
                      dec_bounds=(-0.8, 0.8), npix=npix)


async def _queue_then_start(svc, queries, **submit_kw):
    """The deterministic burst pattern: enqueue everything, then start."""
    tasks = [asyncio.ensure_future(svc.submit(q, **submit_kw))
             for q in queries]
    while svc.queue_depth < len(queries):
        await asyncio.sleep(0.005)
    async with svc:
        return await asyncio.gather(*tasks)


# ----- coalescing correctness ----------------------------------------------

def test_concurrent_compatible_queries_one_dispatch(engine):
    """K same-(layout, npix) queries queued together = ONE engine dispatch,
    every response bitwise-equal to its own serial engine.run."""
    queries = [cheap_q(i) for i in range(6)]
    serial = [engine.run(q, "sql_structured") for q in queries]
    svc = CoaddService(engine, max_batch=16)
    d0 = engine.dispatch_count

    results = asyncio.run(_queue_then_start(svc, queries))

    assert engine.dispatch_count - d0 == 1
    assert svc.stats.dispatches == 1
    assert svc.stats.dispatched_queries == 6
    assert svc.stats.coalesce_factor == 6.0
    for r, s in zip(results, serial):
        np.testing.assert_array_equal(r.coadd, s.coadd)
        np.testing.assert_array_equal(r.depth, s.depth)


def test_identical_inflight_queries_merge(engine):
    """Duplicates of one query merge singleflight-style: one executed plan
    answers every copy, counted in merged_inflight."""
    q = cheap_q(0)
    serial = engine.run(q, "sql_structured")
    svc = CoaddService(engine)

    results = asyncio.run(_queue_then_start(svc, [q, q, q, q]))

    assert svc.stats.dispatches == 1
    assert svc.stats.merged_inflight == 3
    for r in results:
        np.testing.assert_array_equal(r.coadd, serial.coadd)
        np.testing.assert_array_equal(r.depth, serial.depth)


def test_incompatible_npix_split_into_groups(engine):
    """Different npix cannot stack (static scan shape): two groups, two
    dispatches, still bitwise-correct."""
    qs = [cheap_q(0, npix=48), cheap_q(1, npix=48), cheap_q(2, npix=32)]
    serial = [engine.run(q, "sql_structured") for q in qs]
    svc = CoaddService(engine)

    results = asyncio.run(_queue_then_start(svc, qs))

    assert svc.stats.dispatches == 2
    for r, s in zip(results, serial):
        np.testing.assert_array_equal(r.coadd, s.coadd)


# ----- admission / QoS ------------------------------------------------------

def test_cheap_query_not_queued_behind_monsters(engine):
    """Weighted-fair classes: with a convoy of expensive full-survey
    queries queued ahead of one cheap query, the cheap dispatch goes
    first — its latency is bounded by its own dispatch, not the convoy."""
    order = []

    async def scenario():
        svc = CoaddService(engine, cheap_budget=4)
        convoy = [monster_q(96), monster_q(112), monster_q(80)]

        async def client(tag, q):
            await svc.submit(q)
            order.append(tag)

        tasks = [asyncio.ensure_future(client(f"monster{i}", q))
                 for i, q in enumerate(convoy)]
        tasks.append(asyncio.ensure_future(client("cheap", cheap_q(0))))
        while svc.queue_depth < 4:
            await asyncio.sleep(0.005)
        async with svc:
            await asyncio.gather(*tasks)
        return svc

    svc = asyncio.run(scenario())
    assert order[0] == "cheap"
    assert svc.stats.cheap_dispatches == 1
    assert svc.stats.expensive_dispatches == 3  # distinct npix: no stacking


def test_overload_sheds_typed_queue_full(engine):
    """Admission beyond max_queue open requests sheds `Overloaded`
    immediately — before any engine work — and counts it."""

    async def scenario():
        svc = CoaddService(engine, max_queue=2)
        tasks = [asyncio.ensure_future(svc.submit(cheap_q(i)))
                 for i in range(5)]
        await asyncio.sleep(0)  # let every submit hit admission
        async with svc:
            return svc, await asyncio.gather(*tasks, return_exceptions=True)

    svc, results = asyncio.run(scenario())
    shed = [r for r in results if isinstance(r, Overloaded)]
    served = [r for r in results if not isinstance(r, Exception)]
    assert len(shed) == 3 and len(served) == 2
    assert all(e.reason == "queue_full" for e in shed)
    assert svc.stats.shed_queue_full == 3
    assert svc.stats.completed == 2


def test_tenant_inflight_cap(engine):
    """One tenant cannot occupy the queue past its cap; other tenants are
    unaffected."""

    async def scenario():
        svc = CoaddService(engine, tenant_inflight=1)
        t = [asyncio.ensure_future(svc.submit(cheap_q(0), tenant="hog")),
             asyncio.ensure_future(svc.submit(cheap_q(1), tenant="hog")),
             asyncio.ensure_future(svc.submit(cheap_q(2), tenant="polite"))]
        await asyncio.sleep(0)
        async with svc:
            return svc, await asyncio.gather(*t, return_exceptions=True)

    svc, results = asyncio.run(scenario())
    assert isinstance(results[1], Overloaded)
    assert results[1].reason == "tenant_cap"
    assert not isinstance(results[0], Exception)
    assert not isinstance(results[2], Exception)
    assert svc.stats.shed_tenant_cap == 1


# ----- result cache ---------------------------------------------------------

def test_result_cache_bitwise_parity_and_counters(engine):
    """A repeat query is served from the result cache — same pixels
    bitwise, no new dispatch, hit counter incremented."""
    q = cheap_q(3)

    async def scenario():
        async with CoaddService(engine) as svc:
            first = await svc.submit(q)
            d = svc.stats.dispatches
            again = await svc.submit(q)
            return svc, d, first, again

    svc, d_after_first, first, again = asyncio.run(scenario())
    assert svc.stats.cache_hits == 1
    assert svc.stats.dispatches == d_after_first  # no second dispatch
    np.testing.assert_array_equal(first.coadd, again.coadd)
    np.testing.assert_array_equal(first.depth, again.depth)
    serial = engine.run(q, "sql_structured")
    np.testing.assert_array_equal(again.coadd, serial.coadd)


def test_result_key_tracks_psf_state(survey):
    """The cache key carries the live PSF state: retuning the engine
    changes the key, so stale matched pixels can never serve."""
    eng = CoaddEngine(survey, pack_capacity=16)
    plan = eng.plan(cheap_q(0), "sql_structured")
    k0 = eng.result_key(plan)
    eng.match_psf_sigma = 2.0
    plan2 = eng.plan(cheap_q(0), "sql_structured")
    assert eng.result_key(plan2) != k0


def test_queued_duplicate_served_from_cache_after_first_completes(engine):
    """A request whose identical twin completed while it sat in the queue
    resolves from the cache at drain time, not by re-dispatching."""
    q_hot = cheap_q(5)

    async def scenario():
        async with CoaddService(engine) as svc:
            await svc.submit(q_hot)  # populate cache
            r = await svc.submit(q_hot)
            return svc, r

    svc, r = asyncio.run(scenario())
    assert svc.stats.cache_hits == 1
    serial = engine.run(q_hot, "sql_structured")
    np.testing.assert_array_equal(r.coadd, serial.coadd)


# ----- brick routing (§9) ---------------------------------------------------

def test_brick_aligned_queries_route_to_mosaic(survey):
    """With use_bricks on, an aligned query answers on the lattice grid
    (bitwise `run_window` parity), tallies popularity, and a second
    service sees the now-warm cover."""
    eng = CoaddEngine(survey, pack_capacity=16, brick_npix=32)
    q = eng.brick_grid.window_query(1, 2, 1, 2, "r")
    ref = eng.run_window(q, "sql_structured")

    async def one(svc_kwargs=None):
        async with CoaddService(eng, use_bricks=True) as svc:
            r = await svc.submit(q)
        return svc, r

    svc1, r1 = asyncio.run(one())
    assert svc1.stats.brick_routed == 1
    np.testing.assert_array_equal(r1.coadd, ref.coadd)
    np.testing.assert_array_equal(r1.depth, ref.depth)
    # cold first touch: a miss tally, inline materialization warmed it
    assert svc1.brick_popularity[("r", 1, 2, 1, 2)] == [0, 1]

    svc2, r2 = asyncio.run(one())
    # now warm: served as a pure mosaic of stored tiles, hit tally
    assert svc2.brick_popularity[("r", 1, 2, 1, 2)] == [1, 0]
    assert svc2.stats.bricks_hit >= 1
    np.testing.assert_array_equal(r2.coadd, ref.coadd)

    # unaligned queries are untouched by routing
    async def unaligned():
        async with CoaddService(eng, use_bricks=True) as svc:
            await svc.submit(cheap_q(0))
            return svc

    svc3 = asyncio.run(unaligned())
    assert svc3.stats.brick_routed == 0


# ----- chaos under load (§8) ------------------------------------------------

def test_transient_fault_under_load_clients_unaffected(survey):
    """An injected transient upload failure during a concurrent burst is
    retried inside the engine; every client still gets clean bitwise
    pixels and the service surfaces the retry count."""
    probe = CoaddEngine(survey, pack_capacity=8)
    ds = probe.exec_dataset("structured")[0]
    budget = max(ds.chunk_nbytes(0, ds.n_packs) // 4, 1)

    def streaming(injector=None):
        return CoaddEngine(survey, pack_capacity=8,
                           device_budget_bytes=budget,
                           stream_chunk_packs=2, fault_backoff_s=1e-4,
                           fault_injector=injector)

    queries = [cheap_q(i) for i in range(4)]
    clean = [streaming().run(q, "sql_structured") for q in queries]

    inj = ChaosInjector(FaultSchedule(upload_fail_ordinals=(0,)))
    eng = streaming(injector=inj)
    svc = CoaddService(eng)
    results = asyncio.run(_queue_then_start(svc, queries))

    assert inj.injected["upload_fail"] == 1
    assert svc.stats.retries >= 1
    assert svc.stats.failed == 0
    assert svc.stats.completed == len(queries)
    for r, c in zip(results, clean):
        np.testing.assert_array_equal(r.coadd, c.coadd)
        np.testing.assert_array_equal(r.depth, c.depth)


# ----- telemetry ------------------------------------------------------------

def test_service_stats_snapshot_shape(engine):
    """snapshot() is JSON-ready and carries the derived telemetry."""
    svc = CoaddService(engine)
    results = asyncio.run(_queue_then_start(svc, [cheap_q(0), cheap_q(1)]))
    assert len(results) == 2
    snap = svc.stats.snapshot()
    for field in ("submitted", "admitted", "dispatches", "coalesce_factor",
                  "p50_ms", "p95_ms", "p99_ms", "queue_depth_peak"):
        assert field in snap
    assert snap["submitted"] == 2
    assert snap["p95_ms"] >= 0.0
    import json
    json.dumps(snap)
