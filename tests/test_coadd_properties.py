"""Property-based tests (hypothesis) for the coadd system invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CoaddQuery, SpatialIndex, SurveyConfig, make_survey
from repro.core.engine import _coadd_batch, _query_vec
from repro.core.mapper import query_grid_sky
from repro.core.prefilter import camcol_dec_table, glob_file_mask

SURVEY = make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=60,
                                  height=16, width=16))
INDEX = SpatialIndex.build(SURVEY)
CAMCOL = camcol_dec_table(SURVEY)
TAB = SURVEY.meta_table()


def _run_ids(ids, query):
    ids = list(ids)
    px = jnp.asarray(np.stack([SURVEY.images[i].pixels for i in ids]))
    wv = jnp.asarray(np.stack([SURVEY.images[i].wcs.to_vector() for i in ids]))
    ints = {k: jnp.asarray(TAB[k][ids]) for k in ("image_id", "run", "camcol", "band_id", "field")}
    floats = {k: jnp.asarray(TAB[k][ids]) for k in ("t_obs", "ra_min", "ra_max", "dec_min", "dec_max")}
    gr, gd = query_grid_sky(query)
    c, d, n = _coadd_batch(px, wv, ints, floats, jnp.asarray(_query_vec(query)),
                           jnp.asarray(gr), jnp.asarray(gd))
    return np.asarray(c), np.asarray(d), int(n)


QUERIES = st.builds(
    lambda ra0, dra, dec0, ddec, band: CoaddQuery(
        band=band, ra_bounds=(ra0, ra0 + dra), dec_bounds=(dec0, dec0 + ddec), npix=16
    ),
    ra0=st.floats(37.0, 37.8), dra=st.floats(0.1, 0.4),
    dec0=st.floats(-1.0, 0.6), ddec=st.floats(0.1, 0.4),
    band=st.sampled_from(["u", "g", "r", "i", "z"]),
)


@settings(max_examples=15, deadline=None)
@given(q=QUERIES)
def test_prefilter_is_sound(q):
    """Glob prefilter never drops a truly-overlapping image (no false negatives)."""
    exact = set(INDEX.select(q).tolist())
    glob = set(TAB["image_id"][glob_file_mask(TAB, q, CAMCOL)].tolist())
    assert exact <= glob


@settings(max_examples=10, deadline=None)
@given(q=QUERIES, data=st.data())
def test_reduce_is_permutation_invariant(q, data):
    ids = INDEX.select(q)
    if len(ids) < 2:
        return
    perm = data.draw(st.permutations(list(ids)))
    c1, d1, _ = _run_ids(list(ids), q)
    c2, d2, _ = _run_ids(perm, q)
    np.testing.assert_allclose(c1, c2, atol=1e-3)
    np.testing.assert_array_equal(d1, d2)


@settings(max_examples=10, deadline=None)
@given(q=QUERIES)
def test_coadd_is_additive(q):
    """coadd(A ∪ B) = coadd(A) + coadd(B) for disjoint A, B (monoid hom)."""
    ids = list(INDEX.select(q))
    if len(ids) < 2:
        return
    mid = len(ids) // 2
    ca, da, _ = _run_ids(ids[:mid], q)
    cb, db, _ = _run_ids(ids[mid:], q)
    cab, dab, _ = _run_ids(ids, q)
    np.testing.assert_allclose(ca + cb, cab, atol=1e-3)
    np.testing.assert_array_equal(da + db, dab)


@settings(max_examples=10, deadline=None)
@given(q=QUERIES, k=st.integers(2, 4))
def test_k_copies_scale_linearly(q, k):
    ids = list(INDEX.select(q))
    if not ids:
        return
    c1, d1, _ = _run_ids([ids[0]], q)
    ck, dk, _ = _run_ids([ids[0]] * k, q)
    np.testing.assert_allclose(ck, k * c1, rtol=1e-5, atol=1e-3)
    np.testing.assert_array_equal(dk, k * d1)


@settings(max_examples=10, deadline=None)
@given(q=QUERIES)
def test_mapper_discards_false_positives(q):
    """Images outside the query bounds/band contribute exactly zero."""
    all_ids = set(TAB["image_id"].tolist())
    exact = set(INDEX.select(q).tolist())
    outside = sorted(all_ids - exact)[:8]
    if not outside:
        return
    c, d, n = _run_ids(outside, q)
    assert n == 0
    assert np.all(c == 0) and np.all(d == 0)
