"""Property-based suite for measured-PSF homogenization (ISSUE 5 satellite).

Four properties pin the `psf.homogenization_bank` contract:

1. **Flux conservation** — every matching kernel sums to 1, so homogenizing
   never creates or destroys flux.
2. **Target fidelity** — a point source seen through a measured
   (elliptical-Moffat, non-Gaussian) PSF, convolved with its matching
   kernel, reproduces the target Gaussian PSF to <= 1e-3 RMS.
3. **Gaussian closure** — Gaussian stamps reproduce the existing separable
   `matching_kernel_bank` path (the measured machinery degrades to the
   analytic case).
4. **Monotonicity** — matching never deconvolves: stamps already wider
   than the target clamp to delta kernels (with a warning), and the
   homogenized width is never below the input width.

Each property is a plain ``_check_*`` helper driven two ways: a seeded
deterministic grid (always runs, keeps the properties in the tier-1 lane
even where hypothesis isn't installed) and a hypothesis `@given` search
(runs wherever hypothesis is available; CI's nightly lane runs it with a
fixed seed and ``--hypothesis-show-statistics``).
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import psf
from repro.core.survey import render_psf_stamp

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # the deterministic grids below still run
    HAVE_HYPOTHESIS = False

# A wider tap grid than the survey default (13): the properties quantify
# kernel *fidelity*, so the grid must not be the limiting factor — at 17
# taps the worst-domain RMS is ~5e-4, a 2x margin under the 1e-3 bar,
# while the same code path serves both widths.
STAMP = 17


def _moffat(sigma, e1, e2, beta=3.5, size=STAMP):
    return np.asarray(render_psf_stamp(sigma, size, beta, e1, e2), np.float64)


def _apply(stamp, kernel):
    return np.asarray(psf.convolve_2d(jnp.asarray(stamp), jnp.asarray(kernel)))


# Seeded deterministic parameter grid: (sigma_image, sigma_target, e1, e2).
_rng = np.random.default_rng(82)
GRID = [
    (
        float(_rng.uniform(0.8, 1.45)),
        float(_rng.uniform(2.0, 2.6)),
        float(_rng.uniform(-0.12, 0.12)),
        float(_rng.uniform(-0.12, 0.12)),
    )
    for _ in range(8)
]


# ----- property 1: flux conservation -----

def _check_flux_conserved(sigma, target, e1, e2):
    stamp = _moffat(sigma, e1, e2)
    bank = psf.homogenization_bank(
        np.asarray([stamp]), np.asarray([sigma]), target
    )
    np.testing.assert_allclose(bank.sum(axis=(-2, -1)), 1.0, atol=1e-5)
    # ...and therefore convolution preserves total image flux.
    img = np.full((24, 24), 3.0, np.float64)
    out = _apply(img, bank[0])
    np.testing.assert_allclose(out.sum(), img.sum(), rtol=1e-5)


@pytest.mark.parametrize("sigma,target,e1,e2", GRID)
def test_flux_conserved_grid(sigma, target, e1, e2):
    _check_flux_conserved(sigma, target, e1, e2)


# ----- property 2: point source homogenizes to the target PSF -----

def _check_point_source_matches_target(sigma, target, e1, e2):
    """A point source imaged through the measured PSF *is* the stamp;
    homogenized, it must become the target PSF — the acceptance bar is
    1e-3 RMS (ISSUE 5)."""
    stamp = _moffat(sigma, e1, e2)
    bank = psf.homogenization_bank(
        np.asarray([stamp]), np.asarray([sigma]), target
    )
    out = _apply(stamp, bank[0])
    target_img = psf.gaussian_stamp(target, STAMP)
    rms = float(np.sqrt(((out - target_img) ** 2).mean()))
    assert rms <= 1e-3, (rms, sigma, target, e1, e2)


@pytest.mark.parametrize("sigma,target,e1,e2", GRID)
def test_point_source_matches_target_grid(sigma, target, e1, e2):
    _check_point_source_matches_target(sigma, target, e1, e2)


# ----- property 3: Gaussian stamps reproduce the separable path -----

def _check_gaussian_closure(sigma, target):
    """homogenization_bank(Gaussian stamps) == matching_kernel_bank applied
    image-for-image: the measured path degrades to the analytic one."""
    stamp = np.asarray(render_psf_stamp(sigma, STAMP, beta=None), np.float64)
    bank2d = psf.homogenization_bank(
        np.asarray([stamp]), np.asarray([sigma]), target
    )
    bank1d = psf.matching_kernel_bank(
        np.asarray([sigma]), target, radius=(STAMP - 1) // 2
    )
    img = np.asarray(psf.gaussian_stamp(sigma, 33), np.float32)
    out2d = np.asarray(
        psf.convolve_batch(jnp.asarray(img)[None], jnp.asarray(bank2d))
    )[0]
    out1d = np.asarray(
        psf.convolve_batch(jnp.asarray(img)[None], jnp.asarray(bank1d))
    )[0]
    assert np.abs(out2d - out1d).max() < 5e-3, (sigma, target)


@pytest.mark.parametrize(
    "sigma,target", [(s, t) for s, t, _, _ in GRID[:5]]
)
def test_gaussian_closure_grid(sigma, target):
    _check_gaussian_closure(sigma, target)


# ----- property 4: matching is monotone (never deconvolves) -----

def _check_monotone_clamp(sigma, e1, e2):
    """A stamp wider than the target clamps to a delta (+warns), and the
    homogenized width never drops below the input width."""
    stamp = _moffat(sigma, e1, e2)
    narrow_target = 0.5 * float(psf.stamp_sigma(stamp))
    with pytest.warns(RuntimeWarning, match="never deconvolves"):
        bank = psf.homogenization_bank(
            np.asarray([stamp]), np.asarray([sigma]), narrow_target
        )
    delta = np.zeros((STAMP, STAMP), np.float32)
    delta[(STAMP - 1) // 2, (STAMP - 1) // 2] = 1.0
    np.testing.assert_array_equal(bank[0], delta)
    # Widening direction: output width >= input width.
    wide_target = 2.8
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no clamp warning expected here
        bank_w = psf.homogenization_bank(
            np.asarray([stamp]), np.asarray([sigma]), wide_target
        )
    out = _apply(stamp, bank_w[0])
    assert psf.stamp_sigma(out) >= psf.stamp_sigma(stamp) - 1e-6


@pytest.mark.parametrize("sigma,e1,e2", [(s, e1, e2) for s, _, e1, e2 in GRID])
def test_monotone_clamp_grid(sigma, e1, e2):
    _check_monotone_clamp(sigma, e1, e2)


def test_bank_matches_single_kernel_reference():
    """The bank's batched Fourier solve must equal `homogenization_kernel`
    slot-for-slot — the single-stamp function is the readable reference
    implementation, and this pin is what keeps the two from diverging."""
    rng = np.random.default_rng(7)
    stamps = np.stack([
        _moffat(float(s), float(e1), float(e2))
        for s, e1, e2 in rng.uniform([0.9, -0.1, -0.1], [1.4, 0.1, 0.1], (6, 3))
    ])
    target = 2.2
    bank = psf.homogenization_bank(stamps, np.full(6, 1.2), target)
    ref = np.stack([
        psf.homogenization_kernel(st, psf.gaussian_stamp(target, STAMP))
        for st in stamps
    ]).astype(np.float32)
    np.testing.assert_array_equal(bank, ref)


def test_engine_retune_rebuilds_bank():
    """Regression: retuning match_psf_sigma on a live engine must not reuse
    the previous target's kernel bank (caches are keyed per target)."""
    from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey

    sv = make_survey(SurveyConfig(n_runs=2, n_fields=3, n_sources=40,
                                  height=16, width=16))
    q = CoaddQuery(band="r", ra_bounds=(37.2, 37.7), dec_bounds=(-0.5, 0.3),
                   npix=32)
    eng = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.0)
    r_20 = eng.run(q, "sql_structured")
    eng.match_psf_sigma = 2.6
    r_26_retuned = eng.run(q, "sql_structured")
    fresh = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.6)
    r_26_fresh = fresh.run(q, "sql_structured")
    np.testing.assert_array_equal(r_26_retuned.coadd, r_26_fresh.coadd)
    assert np.abs(r_26_retuned.coadd - r_20.coadd).max() > 1e-3
    # ...and must not leak the old target's whole-layout matched copy or
    # device bank (the eager manager never evicts; drop is explicit).
    assert eng.residency.n_resident == 1
    assert len(eng._psf_device) == 1 and len(eng._psf_banks) == 1
    # Toggling the measured-mode knob is the same hazard: the Gaussian
    # fallback must not be served the stale measured bank.
    eng.measured_psf = False
    r_fallback = eng.run(q, "sql_structured")
    fresh_fb = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.6,
                           measured_psf=False)
    np.testing.assert_array_equal(
        r_fallback.coadd, fresh_fb.run(q, "sql_structured").coadd
    )
    assert np.abs(r_fallback.coadd - r_26_retuned.coadd).max() > 1e-4


def test_empty_slots_get_delta_rows():
    """sigma<=0 or zero-sum stamps (padded slots) must yield exact deltas
    and never widen or warn."""
    stamp = _moffat(1.2, 0.05, -0.03)
    zeros = np.zeros_like(stamp)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        bank = psf.homogenization_bank(
            np.stack([stamp, zeros, stamp]),
            np.asarray([1.2, 0.0, -1.0]),
            2.0,
        )
    delta = np.zeros((STAMP, STAMP), np.float32)
    delta[(STAMP - 1) // 2, (STAMP - 1) // 2] = 1.0
    np.testing.assert_array_equal(bank[1], delta)
    np.testing.assert_array_equal(bank[2], delta)
    assert np.abs(bank[0] - delta).max() > 1e-3  # real slot really matches


# ----- hypothesis-driven search over the same properties -----

if HAVE_HYPOTHESIS:
    _common = settings(
        deadline=None,
        max_examples=20,
        suppress_health_check=[HealthCheck.too_slow],
    )
    # sigma stays below ~1.45: a beta=3.5 Moffat's second-moment width is
    # ~1.28x its Gaussian-equivalent sigma, so wider seeing crosses the
    # target and (correctly) clamps — the clamp property tests that region.
    _sigma = st.floats(0.8, 1.45)
    _target = st.floats(2.0, 2.6)
    _e = st.floats(-0.12, 0.12)

    @_common
    @given(sigma=_sigma, target=_target, e1=_e, e2=_e)
    def test_flux_conserved_hypothesis(sigma, target, e1, e2):
        _check_flux_conserved(sigma, target, e1, e2)

    @_common
    @given(sigma=_sigma, target=_target, e1=_e, e2=_e)
    def test_point_source_matches_target_hypothesis(sigma, target, e1, e2):
        _check_point_source_matches_target(sigma, target, e1, e2)

    @_common
    @given(sigma=_sigma, target=_target)
    def test_gaussian_closure_hypothesis(sigma, target):
        _check_gaussian_closure(sigma, target)

    @_common
    @given(sigma=_sigma, e1=_e, e2=_e)
    def test_monotone_clamp_hypothesis(sigma, e1, e2):
        _check_monotone_clamp(sigma, e1, e2)
