"""Subprocess worker for the crash-restart drills (NOT a test module).

`tests/test_durable.py` spawns this script, SIGKILLs it at a seeded
durable-commit stage (via `durable.set_crash_hook`), then re-runs it
against the same journal directory and asserts bitwise parity with the
uninterrupted run.  It doubles as the shared factory for the drill
engines so the parent test process builds *identical* references.
"""
import argparse
import json
import os
import signal
import sys

import numpy as np

from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey
from repro.core import durable

# The test_faults chaos archive: 2 streaming windows for QUERY under a
# 4x-oversubscribed budget, and a 10-brick (brick_deg=0.5) lattice.
SURVEY_KW = dict(n_runs=2, n_fields=4, n_sources=60, height=16, width=16)
QUERY_KW = dict(band="r", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3),
                npix=32)
BRICK_KW = dict(brick_deg=0.5, brick_npix=32)


def build_survey():
    return make_survey(SurveyConfig(**SURVEY_KW))


def build_query():
    return CoaddQuery(**QUERY_KW)


def build_engine(survey, journal_dir=None, **kw):
    """A 4x-oversubscribed streaming engine, optionally durable."""
    probe = CoaddEngine(survey, pack_capacity=8)
    ds = probe.exec_dataset("structured")[0]
    budget = max(ds.chunk_nbytes(0, ds.n_packs) // 4, 1)
    kw.setdefault("stream_chunk_packs", 2)
    return CoaddEngine(survey, pack_capacity=8, device_budget_bytes=budget,
                       fault_backoff_s=1e-4, journal_dir=journal_dir,
                       **BRICK_KW, **kw)


def install_crash(spec: str) -> None:
    """Arm SIGKILL at the Nth firing of a durable commit stage.

    ``spec`` is ``"<stage>:<ordinal>"`` with stage one of
    `durable.CRASH_STAGES`; the process dies *at* that point, mid-commit.
    """
    stage, ordinal = spec.rsplit(":", 1)
    ordinal = int(ordinal)
    if stage not in durable.CRASH_STAGES:
        raise SystemExit(f"unknown crash stage {stage!r}")
    seen = {"n": 0}

    def hook(s: str) -> None:
        if s != stage:
            return
        if seen["n"] == ordinal:
            os.kill(os.getpid(), signal.SIGKILL)
        seen["n"] += 1

    durable.set_crash_hook(hook)


def run_stream(journal_dir: str, method: str):
    eng = build_engine(build_survey(), journal_dir=journal_dir)
    res = eng.run(build_query(), method)
    stats = {
        "resumed_windows": res.stats.resumed_windows,
        "windows": res.stats.windows,
        "dispatches": res.stats.dispatches,
        "jobs_left": eng.journal_store.jobs(),
    }
    return np.asarray(res.coadd), np.asarray(res.depth), stats


def run_bricks(journal_dir: str, method: str):
    eng = build_engine(build_survey(), journal_dir=journal_dir)
    report = eng.materialize_bricks(bands=("r",), method=method)
    wq = eng.brick_grid.window_query(0, 2, 0, 2, "r")
    res = eng.run(wq, method, use_bricks=True)
    stats = {
        "resumed_windows": sum(t.resumed_windows for t in report.tasks),
        "completed": report.completed,
        "skipped": report.skipped,
        "n_bricks": len(report.tasks),
        "disk_loads": eng.brick_store.disk_loads,
        "bricks_served": res.stats.bricks_hit + res.stats.bricks_spilled,
        "jobs_left": eng.journal_store.jobs(),
    }
    return np.asarray(res.coadd), np.asarray(res.depth), stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--journal-dir", required=True)
    ap.add_argument("--out", required=True, help="npz output path")
    ap.add_argument("--mode", choices=("stream", "bricks"), default="stream")
    ap.add_argument("--method", default="sql_structured")
    ap.add_argument("--crash", default=None, help="stage:ordinal SIGKILL seed")
    args = ap.parse_args(argv)
    if args.crash:
        install_crash(args.crash)
    runner = run_stream if args.mode == "stream" else run_bricks
    coadd, depth, stats = runner(args.journal_dir, args.method)
    np.savez(args.out, coadd=coadd, depth=depth)
    with open(args.out + ".json", "w") as fh:
        json.dump(stats, fh)
    return 0


if __name__ == "__main__":
    sys.exit(main())
