"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoaddQuery, SpatialIndex, SurveyConfig, make_survey
from repro.core.mapper import query_grid_sky


# ------------------------------------------------------------------ warp ---
SURVEY = make_survey(SurveyConfig(n_runs=2, n_fields=3, n_sources=40,
                                  height=24, width=24))


@pytest.mark.parametrize("npix,block_rows", [(16, 8), (32, 8), (32, 16), (64, 8)])
def test_warp_kernel_matches_ref(npix, block_rows):
    from repro.kernels.warp import ops as wops
    from repro.kernels.warp import ref as wref
    q = CoaddQuery(band="r", ra_bounds=(37.1, 37.6), dec_bounds=(-0.5, 0.1), npix=npix)
    ids = SpatialIndex.build(SURVEY).select(q)[:6]
    assert len(ids) > 0
    gr, gd = map(jnp.asarray, query_grid_sky(q))
    px = jnp.asarray(np.stack([SURVEY.images[i].pixels for i in ids]))
    wv = jnp.asarray(np.stack([SURVEY.images[i].wcs.to_vector() for i in ids]))
    acc = jnp.ones((len(ids),), jnp.float32)
    t_r, c_r = wref.warp_batch_ref(px, wv, acc, gr, gd)
    t_k, c_k = wops.warp_batch(px, wv, acc, gr, gd, block_rows=block_rows)
    assert float(jnp.abs(t_r).max()) > 0  # non-trivial
    np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_r), atol=2e-2, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


@pytest.mark.parametrize("npix", [32, 64])
def test_coadd_fused_kernel_matches_ref(npix):
    from repro.kernels.warp import ops as wops
    from repro.kernels.warp import ref as wref
    q = CoaddQuery(band="g", ra_bounds=(37.0, 37.7), dec_bounds=(-0.7, 0.3), npix=npix)
    ids = SpatialIndex.build(SURVEY).select(q)[:8]
    gr, gd = map(jnp.asarray, query_grid_sky(q))
    px = jnp.asarray(np.stack([SURVEY.images[i].pixels for i in ids]))
    wv = jnp.asarray(np.stack([SURVEY.images[i].wcs.to_vector() for i in ids]))
    acc = jnp.ones((len(ids),), jnp.float32)
    c_r, d_r = wref.coadd_fused_ref(px, wv, acc, gr, gd)
    c_k, d_k = wops.coadd_fused(px, wv, acc, gr, gd)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), atol=2e-2, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))


def test_warp_kernel_rejects_on_accept_gate():
    from repro.kernels.warp import ops as wops
    q = CoaddQuery(band="r", ra_bounds=(37.1, 37.6), dec_bounds=(-0.5, 0.1), npix=32)
    ids = SpatialIndex.build(SURVEY).select(q)[:2]
    gr, gd = map(jnp.asarray, query_grid_sky(q))
    px = jnp.asarray(np.stack([SURVEY.images[i].pixels for i in ids]))
    wv = jnp.asarray(np.stack([SURVEY.images[i].wcs.to_vector() for i in ids]))
    t, c = wops.warp_batch(px, wv, jnp.zeros((2,), jnp.float32), gr, gd)
    assert float(jnp.abs(t).max()) == 0 and float(jnp.abs(c).max()) == 0


# ------------------------------------------------------------- attention ---
@pytest.mark.parametrize("hq,hkv,s,d,causal,window,dtype", [
    (4, 4, 128, 32, True, None, jnp.float32),
    (4, 2, 256, 64, True, None, jnp.float32),
    (8, 1, 128, 32, False, None, jnp.float32),
    (4, 2, 256, 64, True, 64, jnp.float32),
    (4, 2, 128, 64, True, None, jnp.bfloat16),
])
def test_flash_attention_sweep(hq, hkv, s, d, causal, window, dtype):
    from repro.kernels.attention import ops as aops
    from repro.kernels.attention.ref import mha_ref
    key = jax.random.PRNGKey(42)
    q = jax.random.normal(key, (2, hq, s, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, hkv, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, hkv, s, d), dtype)
    o_k = aops.flash_attention(q, k, v, causal, window, 64, 64, True)
    o_r = mha_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol, rtol=tol)


def test_flash_attention_grads_match_ref():
    from repro.kernels.attention import ops as aops
    from repro.kernels.attention.ref import mha_ref
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 128, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 32))
    g1 = jax.grad(lambda q, k, v: aops.flash_attention(q, k, v, True, None, 64, 64, True).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: mha_ref(q, k, v, causal=True).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ------------------------------------------------------------------- ssd ---
@pytest.mark.parametrize("t,h,n,p,chunk", [
    (128, 2, 16, 16, 32),
    (256, 3, 32, 16, 64),
    (64, 1, 8, 32, 64),   # chunk > needed
    (192, 2, 16, 16, 64),
])
def test_ssd_kernel_sweep(t, h, n, p, chunk):
    from repro.kernels.ssd import ops as sops
    from repro.kernels.ssd.ref import ssd_batched_ref
    key = jax.random.PRNGKey(1)
    a = jax.nn.sigmoid(jax.random.normal(key, (2, t, h))) * 0.95 + 0.02
    B = jax.random.normal(jax.random.fold_in(key, 1), (2, t, n))
    C = jax.random.normal(jax.random.fold_in(key, 2), (2, t, n))
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, t, h, p))
    y_r = ssd_batched_ref(a, B, C, x)
    y_k = sops.ssd(a, B, C, x, chunk=chunk)
    scale = float(jnp.abs(y_r).max())
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=2e-4 * max(scale, 1.0))
