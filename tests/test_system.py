"""End-to-end behaviour tests for the paper's system."""
import numpy as np

from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey


def test_end_to_end_stacking_improves_snr():
    """The paper's Fig. 2 effect: the stack has higher SNR than one exposure.

    SNR proxy: correlation of (image - background) with the noiseless source
    field rendered from the catalog.
    """
    cfg = SurveyConfig(n_runs=6, n_fields=4, n_sources=80, height=24, width=24,
                       noise_sigma=8.0)
    sv = make_survey(cfg)
    eng = CoaddEngine(sv, pack_capacity=32)
    q = CoaddQuery(band="r", ra_bounds=(37.2, 37.7), dec_bounds=(-0.5, 0.2), npix=64)
    res = eng.run(q, "sql_structured")
    deep = res.depth >= cfg.n_runs - 1
    assert deep.sum() > 200, "query should be well-covered"

    # Per-pixel std of the mean image falls ~ 1/sqrt(depth): compare a single
    # projected exposure's residual noise to the stack's.
    single = CoaddEngine(sv, pack_capacity=32)
    q1 = CoaddQuery(band="r", ra_bounds=q.ra_bounds, dec_bounds=q.dec_bounds,
                    npix=64, time_bounds=(0.0, 99.0))
    res1 = single.run(q1, "sql_structured")
    m_all = res.normalized
    m_one = res1.normalized
    sky = np.median(m_all[deep])
    # background pixels (low signal): noise comparison
    bg = deep & (m_all < sky + 2)
    assert bg.sum() > 50
    noise_stack = np.std(m_all[bg])
    noise_one = np.std(m_one[bg & (res1.depth > 0)])
    assert noise_stack < noise_one * 0.75, (noise_stack, noise_one)


def test_multi_query_job_matches_individual_runs():
    sv = make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=50,
                                  height=16, width=16))
    eng = CoaddEngine(sv, pack_capacity=16)
    qs = [
        CoaddQuery(band="g", ra_bounds=(37.1, 37.5), dec_bounds=(-0.4, 0.1), npix=32),
        CoaddQuery(band="r", ra_bounds=(37.4, 37.9), dec_bounds=(-0.2, 0.4), npix=32),
    ]
    for q in qs:
        a = eng.run(q, "sql_structured")
        b = eng.run(q, "sql_unstructured")
        np.testing.assert_allclose(a.coadd, b.coadd, atol=1e-3)
        np.testing.assert_array_equal(a.depth, b.depth)
