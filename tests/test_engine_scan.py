"""Parity + perf-contract tests for the device-resident one-dispatch engine.

The scan engine (`engine._coadd_scan`) must reproduce the seed per-pack
Python loop (one `_coadd_batch` dispatch per pack / per gathered chunk)
bit-for-comparable on all six methods, while issuing O(1) jit dispatches per
query and zero pack-pixel uploads after the first query on a layout.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoaddEngine, CoaddQuery, METHODS, SurveyConfig, make_survey
from repro.core.engine import _coadd_batch, _query_vec
from repro.core.mapper import query_grid_sky
from repro.core.prefilter import glob_file_mask, glob_pack_mask
from repro.core.seqfile import PackedDataset


@pytest.fixture(scope="module")
def survey():
    return make_survey(SurveyConfig(n_runs=3, n_fields=5, n_sources=100,
                                    height=20, width=20))


QUERY = CoaddQuery(band="r", ra_bounds=(37.3, 37.9), dec_bounds=(-0.5, 0.3), npix=48)


def _seed_loop_packs(eng, layout, pack_ids, query, use_kernel):
    """The seed engine's `_run_packs`: one jit dispatch per pack."""
    ds = eng.dataset(layout)
    grid_ra, grid_dec = map(jnp.asarray, query_grid_sky(query))
    qvec = jnp.asarray(_query_vec(query))
    coadd = jnp.zeros((query.npix, query.npix), jnp.float32)
    depth = jnp.zeros((query.npix, query.npix), jnp.float32)
    contributing = 0
    considered = 0
    for p in pack_ids:
        ints = {k: jnp.asarray(v[p]) for k, v in ds.ints.items()}
        floats = {k: jnp.asarray(v[p]) for k, v in ds.floats.items()}
        c, d, n = _coadd_batch(
            jnp.asarray(ds.pixels[p]), jnp.asarray(ds.wcs[p]), ints, floats,
            qvec, grid_ra, grid_dec, use_kernel=use_kernel,
        )
        coadd = coadd + c
        depth = depth + d
        contributing += int(n)
        considered += int(ds.valid[p].sum())
    return np.asarray(coadd), np.asarray(depth), contributing, considered


def _seed_loop_sql(eng, layout, query, use_kernel):
    """The seed engine's `_sql_gather`: host gather + one dispatch per chunk."""
    ds = eng.dataset(layout)
    ids = eng.sql.select(query)
    cap = ds.capacity
    pad_to = int(np.ceil(max(len(ids), 1) / cap) * cap)
    px, wv, ints_np, floats_np, valid, n_packs = ds.gather(ids, pad_to=pad_to)
    grid_ra, grid_dec = map(jnp.asarray, query_grid_sky(query))
    qvec = jnp.asarray(_query_vec(query))
    coadd = jnp.zeros((query.npix, query.npix), jnp.float32)
    depth = jnp.zeros((query.npix, query.npix), jnp.float32)
    contributing = 0
    for i in range(0, pad_to, cap):
        ints = {k: jnp.asarray(v[i:i + cap]) for k, v in ints_np.items()}
        floats = {k: jnp.asarray(v[i:i + cap]) for k, v in floats_np.items()}
        c, d, n = _coadd_batch(
            jnp.asarray(px[i:i + cap]), jnp.asarray(wv[i:i + cap]), ints,
            floats, qvec, grid_ra, grid_dec, use_kernel=use_kernel,
        )
        coadd = coadd + c
        depth = depth + d
        contributing += int(n)
    return np.asarray(coadd), np.asarray(depth), contributing, len(ids)


def _seed_reference(eng, method, query, use_kernel=False):
    if method in ("raw_fits", "raw_fits_prefiltered"):
        ds = eng.dataset("per_file")
        if method == "raw_fits":
            pack_ids = list(range(ds.n_packs))
        else:
            mask = glob_file_mask(eng.survey.meta_table(), query, eng.camcol_dec)
            pack_ids = np.nonzero(mask)[0].tolist()
        return _seed_loop_packs(eng, "per_file", pack_ids, query, use_kernel)
    if method == "unstructured_seq":
        ds = eng.dataset("unstructured")
        return _seed_loop_packs(
            eng, "unstructured", list(range(ds.n_packs)), query, use_kernel)
    if method == "structured_seq_prefiltered":
        ds = eng.dataset("structured")
        mask = glob_pack_mask(ds, query, eng.camcol_dec)
        return _seed_loop_packs(
            eng, "structured", np.nonzero(mask)[0].tolist(), query, use_kernel)
    layout = "unstructured" if method == "sql_unstructured" else "structured"
    return _seed_loop_sql(eng, layout, query, use_kernel)


@pytest.mark.parametrize("method", [m for m in METHODS])
def test_scan_matches_seed_loop(survey, method):
    eng = CoaddEngine(survey, pack_capacity=16)
    got = eng.run(QUERY, method)
    ref_coadd, ref_depth, ref_contrib, ref_considered = _seed_reference(
        eng, method, QUERY)
    assert ref_depth.max() > 0  # non-trivial query
    # The scan and the seed loop are different XLA programs: CPU codegen may
    # contract the gnomonic trig with fma / vectorize it differently, and the
    # resulting ~ulp jitter in (sx, sy) is amplified by steep source
    # gradients to ~1e-2 on O(100) pixel sums (~1e-4 relative).  Coverage and
    # counts must still be exact.
    np.testing.assert_allclose(got.coadd, ref_coadd, atol=5e-2, rtol=1e-3)
    np.testing.assert_array_equal(got.depth, ref_depth)
    assert got.stats.files_contributing == ref_contrib
    assert got.stats.files_considered == ref_considered
    # Sparse execution (the default) must never scan more than the layout
    # holds, and its budget accounting must be self-consistent.
    exec_ds, _ = eng.exec_dataset(
        "per_file" if method.startswith("raw_fits")
        else ("unstructured" if "unstructured" in method else "structured"))
    assert got.stats.packs_scanned == got.stats.scan_budget <= exec_ds.n_packs
    assert got.stats.packs_gated <= got.stats.packs_scanned


@pytest.mark.parametrize("method", ["sql_structured", "unstructured_seq",
                                    "raw_fits_prefiltered"])
def test_scan_matches_seed_loop_with_kernel(survey, method):
    """use_kernel=True exercises coadd_fused end-to-end through run()."""
    eng = CoaddEngine(survey, pack_capacity=16, use_kernel=True)
    got = eng.run(QUERY, method)
    ref_coadd, ref_depth, _, _ = _seed_reference(eng, method, QUERY,
                                                 use_kernel=True)
    np.testing.assert_allclose(got.coadd, ref_coadd, atol=5e-2, rtol=1e-3)
    np.testing.assert_array_equal(got.depth, ref_depth)
    # And the kernel path agrees with the XLA path on the same engine state.
    eng_x = CoaddEngine(survey, pack_capacity=16, use_kernel=False)
    got_x = eng_x.run(QUERY, method)
    np.testing.assert_allclose(got.coadd, got_x.coadd, atol=5e-2, rtol=1e-3)
    np.testing.assert_array_equal(got.depth, got_x.depth)


def test_dispatch_count_is_o1_in_packs(survey):
    """One jit dispatch per query, regardless of how many packs exist."""
    eng = CoaddEngine(survey, pack_capacity=4)   # many small packs
    n_packs = eng.dataset("per_file").n_packs    # == n_images packs
    assert n_packs > 50
    before = eng.dispatch_count
    r = eng.run(QUERY, "raw_fits")               # touches every pack
    assert eng.dispatch_count - before == 1
    assert r.stats.dispatches == 1
    before = eng.dispatch_count
    r = eng.run(QUERY, "sql_structured")
    assert eng.dispatch_count - before == 1
    assert r.stats.dispatches == 1


def test_second_query_uploads_nothing(survey, monkeypatch):
    """Pack pixels cross host->device once per layout, never per query."""
    eng = CoaddEngine(survey, pack_capacity=16)
    q2 = CoaddQuery(band="g", ra_bounds=(37.2, 37.7), dec_bounds=(-0.4, 0.2),
                    npix=48)
    eng.run(QUERY, "sql_structured")
    uploads_after_first = eng.pack_upload_count
    dev_pixels = eng._device_cache["structured"].pixels

    def _no_more_uploads(self):
        raise AssertionError("pack pixels re-uploaded on a repeat query")

    monkeypatch.setattr(PackedDataset, "to_device", _no_more_uploads)
    eng.run(QUERY, "sql_structured")            # same query again
    eng.run(q2, "sql_structured")               # different query, same layout
    eng.run(q2, "structured_seq_prefiltered")   # different method, same layout
    assert eng.pack_upload_count == uploads_after_first
    assert eng._device_cache["structured"].pixels is dev_pixels


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["xla", "kernel"])
@pytest.mark.parametrize("method", [m for m in METHODS])
def test_run_batch_matches_per_query_run(survey, method, use_kernel):
    """Batched results match per-query run() for all six methods."""
    eng = CoaddEngine(survey, pack_capacity=16, use_kernel=use_kernel)
    q2 = CoaddQuery(band="r", ra_bounds=(37.2, 37.7), dec_bounds=(-0.4, 0.2),
                    npix=48)
    singles = [eng.run(QUERY, method), eng.run(q2, method)]
    batch = eng.run_batch([QUERY, q2], method)
    assert len(batch) == 2
    for s, b in zip(singles, batch):
        # Same engine, same gates: the vmapped scan may vectorize the trig
        # differently than the single-query scan, so allow ulp-level jitter.
        np.testing.assert_allclose(b.coadd, s.coadd, atol=1e-3, rtol=1e-4)
        np.testing.assert_array_equal(b.depth, s.depth)
        assert b.stats.files_contributing == s.stats.files_contributing
        assert b.stats.files_considered == s.stats.files_considered


def test_run_batch_single_dispatch_no_reupload(survey, monkeypatch):
    """K queries = ONE jitted dispatch and ZERO pack re-uploads."""
    eng = CoaddEngine(survey, pack_capacity=16)
    eng.run(QUERY, "sql_structured")      # warm: layout uploaded once here
    uploads = eng.pack_upload_count

    def _no_more_uploads(self):
        raise AssertionError("pack pixels re-uploaded by run_batch")

    monkeypatch.setattr(PackedDataset, "to_device", _no_more_uploads)
    queries = [
        CoaddQuery(band="r", ra_bounds=(37.2 + 0.1 * i, 37.8 + 0.1 * i),
                   dec_bounds=(-0.5, 0.3), npix=48)
        for i in range(3)
    ]
    before = eng.dispatch_count
    results = eng.run_batch(queries, "sql_structured")
    assert eng.dispatch_count - before == 1
    assert eng.pack_upload_count == uploads
    assert sum(r.stats.dispatches for r in results) == 1
    assert eng.run_batch([], "sql_structured") == []


def test_distributed_mesh_resident_no_regather(survey, monkeypatch):
    """Second job over the same mesh: 0 host pixel gathers, 0 re-shards."""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = CoaddEngine(survey, pack_capacity=16)
    q = CoaddQuery(band="r", ra_bounds=(37.3, 37.9), dec_bounds=(-0.5, 0.3),
                   npix=32)
    r1 = eng.run_distributed([q], mesh)[0]
    assert r1.depth.max() > 0
    assert eng.mesh_upload_count == 1
    # Cache key carries the PSF target (None when matching is off).
    mds = eng._mesh_cache[("structured", mesh, ("data", "model"), None)]

    def _no_gather(self, *a, **k):
        raise AssertionError("host pixel gather on a repeat distributed job")

    monkeypatch.setattr(PackedDataset, "gather", _no_gather)
    monkeypatch.setattr(PackedDataset, "to_mesh", _no_gather)
    q2 = CoaddQuery(band="g", ra_bounds=(37.2, 37.7), dec_bounds=(-0.4, 0.2),
                    npix=32)
    r2 = eng.run_distributed([q2], mesh)[0]
    assert eng.mesh_upload_count == 1
    assert eng._mesh_cache[("structured", mesh, ("data", "model"), None)] is mds
    # And the cached-shard answer still matches the single-host path.
    ref = eng.run(q2, "sql_structured")
    np.testing.assert_allclose(r2.coadd, ref.coadd, atol=1e-2, rtol=1e-4)
    np.testing.assert_array_equal(r2.depth, ref.depth)


def test_distributed_empty_jobs(survey):
    """Edge guards: empty query list, and a selection matching nothing."""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = CoaddEngine(survey, pack_capacity=16)
    assert eng.run_distributed([], mesh) == []
    # Far outside the survey footprint: zero coadds, no phantom image padded
    # through the map stage, no device dispatch at all.
    q = CoaddQuery(band="r", ra_bounds=(200.0, 201.0), dec_bounds=(50.0, 51.0),
                   npix=32)
    before = eng.dispatch_count
    res = eng.run_distributed([q, q], mesh)
    assert eng.dispatch_count == before
    assert len(res) == 2
    for r in res:
        assert r.stats.dispatches == 0
        assert r.stats.files_considered == 0
        assert np.all(r.coadd == 0) and np.all(r.depth == 0)


@pytest.mark.slow
def test_distributed_respects_use_kernel(survey):
    """use_kernel threads through run_distributed's shard_map body."""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    q = CoaddQuery(band="r", ra_bounds=(37.3, 37.9), dec_bounds=(-0.5, 0.3),
                   npix=32)
    eng = CoaddEngine(survey, pack_capacity=16)
    eng_k = CoaddEngine(survey, pack_capacity=16, use_kernel=True)
    r = eng.run_distributed([q], mesh)[0]
    r_k = eng_k.run_distributed([q], mesh)[0]
    assert r_k.depth.max() > 0
    np.testing.assert_allclose(r_k.coadd, r.coadd, atol=2e-2, rtol=1e-4)
    np.testing.assert_array_equal(r_k.depth, r.depth)
