"""Durable fault domain drills (DESIGN.md §8): crash-safe journals,
persistent brick store, and process-death recovery.

Three layers of proof:

* **Unit**: `DiskJournal` / `JournalStore` / `BrickSpill` commit atomically,
  replay valid prefixes of corrupted files, and never report a record whose
  payload does not hash back to its manifest digest.
* **In-process chaos**: a killed streaming query leaves an on-disk journal
  that a *fresh engine* resumes bitwise — even after the journal is
  truncated, bit-flipped, or digest-mismatched under it.
* **Process death**: `durable_worker.py` subprocesses SIGKILL themselves at
  seeded commit stages (including mid-segment-write); a restarted process
  replays the journal and must match the uninterrupted run bitwise with
  ``resumed_windows > 0``.
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    ChaosInjector,
    CoaddEngine,
    FaultSchedule,
    METHODS,
    QueryKilled,
    ResidencyManager,
    ScanWindow,
    WindowTracker,
    make_survey,
    SurveyConfig,
)
from repro.core.durable import BrickSpill, DiskJournal, JournalStore

import durable_worker as dw

REPO = Path(__file__).resolve().parents[1]
WORKER = Path(dw.__file__).resolve()
QUERY = dw.build_query()


@pytest.fixture(scope="module")
def survey():
    return dw.build_survey()


_REFS = {}


def _reference(survey, method):
    """The uninterrupted in-process run (no journal dir): the parity oracle.

    CPU jit execution is cross-process deterministic, so the subprocess
    drills compare against this without a reference subprocess.
    """
    if method not in _REFS:
        _REFS[method] = dw.build_engine(survey).run(QUERY, method)
    return _REFS[method]


def _run_worker(args, expect_kill=False, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(WORKER), *args],
        cwd=str(REPO), env=env, capture_output=True, text=True,
        timeout=timeout,
    )
    if expect_kill:
        assert proc.returncode == -signal.SIGKILL, (
            f"worker exited {proc.returncode}, expected SIGKILL\n{proc.stderr}"
        )
    else:
        assert proc.returncode == 0, proc.stderr
    return proc


def _load_out(out):
    with np.load(out) as z:
        coadd, depth = z["coadd"], z["depth"]
    with open(str(out) + ".json") as fh:
        stats = json.load(fh)
    return coadd, depth, stats


# ===== DiskJournal / JournalStore units =====================================

def _parts(seed, n=2):
    rng = np.random.default_rng(seed)
    return tuple(rng.normal(size=(3, 3)).astype(np.float32) for _ in range(n))


def _fill(root, n=3):
    j = DiskJournal(root)
    keys = [(i, i + 1, 2, 4) for i in range(n)]
    for i, k in enumerate(keys):
        j[k] = _parts(i)
    j.close()
    return keys


def test_disk_journal_roundtrip(tmp_path):
    keys = _fill(tmp_path, n=3)
    j = DiskJournal(tmp_path)
    assert len(j) == 3 and j.dropped_records == 0
    for i, k in enumerate(keys):
        assert k in j
        got = j[k]
        for a, b in zip(got, _parts(i)):
            np.testing.assert_array_equal(a, b)
    assert (9, 9, 9, 9) not in j
    j.close()


def test_disk_journal_truncated_tail_segment(tmp_path):
    """A torn final payload write replays the valid prefix, never crashes."""
    keys = _fill(tmp_path, n=3)
    seg = tmp_path / DiskJournal.SEGMENT
    seg.write_bytes(seg.read_bytes()[:-5])
    j = DiskJournal(tmp_path)
    assert sorted(j.keys()) == keys[:2]
    assert j.dropped_records == 1
    # The tail was truncated away: appends go to a consistent offset and a
    # re-replay sees the new record.
    j[(7, 8, 2, 4)] = _parts(7)
    j.close()
    j2 = DiskJournal(tmp_path)
    assert sorted(j2.keys()) == sorted(keys[:2] + [(7, 8, 2, 4)])
    assert j2.dropped_records == 0
    j2.close()


def test_disk_journal_truncated_manifest_line(tmp_path):
    keys = _fill(tmp_path, n=3)
    man = tmp_path / DiskJournal.MANIFEST
    raw = man.read_bytes()
    man.write_bytes(raw[: len(raw) - 10])  # tear the last jsonl line
    j = DiskJournal(tmp_path)
    assert sorted(j.keys()) == keys[:2]
    j.close()


def test_disk_journal_bitflip_payload(tmp_path):
    """A flipped byte in record 1's payload drops it AND its suffix: replay
    is a valid *prefix*, never a subset with holes."""
    keys = _fill(tmp_path, n=3)
    man = tmp_path / DiskJournal.MANIFEST
    off = json.loads(man.read_bytes().splitlines()[1])["off"]
    seg = tmp_path / DiskJournal.SEGMENT
    raw = bytearray(seg.read_bytes())
    raw[off + 12] ^= 0xFF
    seg.write_bytes(bytes(raw))
    j = DiskJournal(tmp_path)
    assert sorted(j.keys()) == keys[:1]
    assert j.dropped_records == 2
    j.close()


def test_disk_journal_manifest_payload_mismatch(tmp_path):
    """A manifest digest that no longer matches its payload is dropped."""
    keys = _fill(tmp_path, n=3)
    man = tmp_path / DiskJournal.MANIFEST
    lines = man.read_bytes().splitlines(keepends=True)
    rec = json.loads(lines[-1])
    rec["sha"] = "0" * 64
    lines[-1] = (json.dumps(rec) + "\n").encode()
    man.write_bytes(b"".join(lines))
    j = DiskJournal(tmp_path)
    assert sorted(j.keys()) == keys[:2]
    assert j.dropped_records == 1
    j.close()


def test_journal_store_open_remove_jobs(tmp_path):
    store = JournalStore(tmp_path)
    j = store.open("job-abc")
    j[(0, 1, 1, 2)] = _parts(0)
    j.close()
    assert store.exists("job-abc")
    assert store.jobs() == ["job-abc"[:32]]
    assert store.remove("job-abc")
    assert store.jobs() == [] and not store.exists("job-abc")
    assert not store.remove("job-abc")  # idempotent
    store.drain_tombs()  # deletion is async; wait for the reaper
    assert not list(Path(tmp_path).glob("*.gc.*"))  # tombs reaped


def test_journal_store_sweeps_stale_orphans(tmp_path):
    store = JournalStore(tmp_path, max_age_s=3600.0)
    store.open("job-old").close()
    store.open("job-new").close()
    old_dir = tmp_path / "job-old"
    past = time.time() - 7200.0
    os.utime(old_dir, (past, past))
    store2 = JournalStore(tmp_path, max_age_s=3600.0)
    assert store2.swept == 1
    assert store2.jobs() == ["job-new"]
    assert not old_dir.exists()


# ===== BrickSpill units =====================================================

def _brick_payload(seed=3):
    rng = np.random.default_rng(seed)
    coadd = rng.normal(size=(8, 8)).astype(np.float32)
    depth = rng.integers(0, 5, size=(8, 8)).astype(np.float32)
    meta = {"partial": False, "uncovered_packs": [], "files_considered": 7,
            "files_contributing": 5}
    return coadd, depth, meta


def test_brick_spill_roundtrip(tmp_path):
    spill = BrickSpill(tmp_path)
    key = ("brick", "r", 0, 1, ("psf", 1.25))
    coadd, depth, meta = _brick_payload()
    spill.save(key, coadd, depth, meta)
    assert spill.contains(key)
    got = spill.load(key)
    assert got is not None
    np.testing.assert_array_equal(got[0], coadd)
    np.testing.assert_array_equal(got[1], depth)
    assert got[2] == meta
    spill.delete(key)
    assert spill.load(key) is None and spill.corrupt_drops == 0


@pytest.mark.parametrize("damage", ["bitflip", "truncate", "garbage"])
def test_brick_spill_corruption_is_a_miss(tmp_path, damage):
    spill = BrickSpill(tmp_path)
    key = ("brick", "r", 2, 2, ())
    spill.save(key, *_brick_payload())
    path = spill._path(key)
    raw = bytearray(path.read_bytes())
    if damage == "bitflip":
        raw[len(raw) // 2] ^= 0xFF
    elif damage == "truncate":
        raw = raw[: len(raw) // 2]
    else:
        raw = bytearray(b"not an npz at all")
    path.write_bytes(bytes(raw))
    assert spill.load(key) is None       # bad digest -> miss, not a crash
    assert spill.corrupt_drops == 1
    assert not path.exists()             # the corpse is reaped
    assert not spill.contains(key)


# ===== in-process crash + corruption recovery ===============================

def _killed_durable_engine(survey, jd, method="sql_structured"):
    """Run QUERY under a kill-after-1-window injector with a disk journal;
    return the surviving on-disk job directory."""
    inj = ChaosInjector(FaultSchedule(kill_after_windows=1))
    eng = dw.build_engine(survey, journal_dir=str(jd), fault_injector=inj)
    with pytest.raises(QueryKilled):
        eng.run(QUERY, method)
    jobs = eng.journal_store.jobs()
    assert len(jobs) == 1
    return jd / "windows" / jobs[0]


def test_fresh_engine_resumes_disk_journal_bitwise(survey, tmp_path):
    method = "sql_structured"
    ref = _reference(survey, method)
    _killed_durable_engine(survey, tmp_path, method)
    eng2 = dw.build_engine(survey, journal_dir=str(tmp_path))
    r = eng2.run(QUERY, method)
    assert r.stats.resumed_windows == 1
    assert r.stats.dispatches == r.stats.windows - 1
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)
    assert eng2.journal_store.jobs() == []  # clean exit GC'd the job


@pytest.mark.parametrize("damage", ["truncate", "bitflip", "sha"])
def test_corrupted_journal_degrades_to_reexecution(survey, tmp_path, damage):
    """Corruption under the journal re-dispatches the lost windows — the
    answer stays bitwise; only the resume accounting degrades."""
    method = "sql_structured"
    ref = _reference(survey, method)
    job_dir = _killed_durable_engine(survey, tmp_path, method)
    seg = job_dir / DiskJournal.SEGMENT
    man = job_dir / DiskJournal.MANIFEST
    if damage == "truncate":
        seg.write_bytes(seg.read_bytes()[:-3])
    elif damage == "bitflip":
        raw = bytearray(seg.read_bytes())
        raw[len(raw) // 2] ^= 0x10
        seg.write_bytes(bytes(raw))
    else:
        rec = json.loads(man.read_bytes().splitlines()[0])
        rec["sha"] = "f" * 64
        man.write_bytes((json.dumps(rec) + "\n").encode())
    eng2 = dw.build_engine(survey, journal_dir=str(tmp_path))
    r = eng2.run(QUERY, method)
    assert r.stats.resumed_windows == 0      # the one journaled window died
    assert r.stats.dispatches == r.stats.windows
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)
    assert eng2.journal_store.jobs() == []


def test_durable_clean_run_is_bitwise_and_leaves_nothing(survey, tmp_path):
    method = "raw_fits_prefiltered"
    ref = _reference(survey, method)
    eng = dw.build_engine(survey, journal_dir=str(tmp_path))
    r = eng.run(QUERY, method)
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)
    assert eng.journal_store.jobs() == []
    assert not list((tmp_path / "windows").glob("*.tmp.*"))


def test_engine_init_sweeps_stale_window_journals(survey, tmp_path):
    eng = dw.build_engine(survey, journal_dir=str(tmp_path))
    eng.journal_store.open("orphan-job").close()
    orphan = tmp_path / "windows" / "orphan-job"
    past = time.time() - 8 * 86400.0
    os.utime(orphan, (past, past))
    eng2 = dw.build_engine(survey, journal_dir=str(tmp_path))
    assert eng2.journal_store.swept == 1
    assert not orphan.exists()


# ===== persistent brick store ===============================================

def test_brick_store_persists_across_engines(survey, tmp_path):
    # chunk_packs=1: the accumulation grouping of per-brick jobs matches the
    # fresh window scan, so parity with `run_window` is bitwise (PR 7 idiom).
    eng = dw.build_engine(survey, journal_dir=str(tmp_path),
                          stream_chunk_packs=1)
    rep = eng.materialize_bricks(bands=("r",))
    n = len(rep.tasks)
    assert rep.completed == n and n > 0
    wq = eng.brick_grid.window_query(0, 2, 0, 2, "r")
    served = eng.run(wq, "sql_structured", use_bricks=True)
    baseline = eng.run_window(wq, "sql_structured")

    eng2 = dw.build_engine(survey, journal_dir=str(tmp_path),
                           stream_chunk_packs=1)
    rep2 = eng2.materialize_bricks(bands=("r",))
    assert rep2.skipped == n and rep2.completed == 0   # all served from disk
    assert eng2.brick_store.disk_loads == n
    served2 = eng2.run(wq, "sql_structured", use_bricks=True)
    assert served2.stats.bricks_hit + served2.stats.bricks_spilled == 4
    np.testing.assert_array_equal(served2.coadd, served.coadd)
    np.testing.assert_array_equal(served2.coadd, baseline.coadd)
    np.testing.assert_array_equal(served2.depth, baseline.depth)


def test_corrupt_spilled_brick_rematerializes(survey, tmp_path):
    eng = dw.build_engine(survey, journal_dir=str(tmp_path),
                          stream_chunk_packs=1)
    rep = eng.materialize_bricks(bands=("r",))
    n = len(rep.tasks)
    files = sorted((tmp_path / "bricks").glob("brick-*.npz"))
    assert len(files) == n
    raw = bytearray(files[0].read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    files[0].write_bytes(bytes(raw))

    eng2 = dw.build_engine(survey, journal_dir=str(tmp_path),
                           stream_chunk_packs=1)
    rep2 = eng2.materialize_bricks(bands=("r",))
    assert rep2.skipped == n - 1 and rep2.completed == 1
    assert eng2.brick_store.spill.corrupt_drops == 1
    wq = eng2.brick_grid.window_query(0, 2, 0, 2, "r")
    served = eng2.run(wq, "sql_structured", use_bricks=True)
    baseline = eng2.run_window(wq, "sql_structured")
    np.testing.assert_array_equal(served.coadd, baseline.coadd)
    np.testing.assert_array_equal(served.depth, baseline.depth)


# ===== SIGKILL process-death drills =========================================

FAST_KILL_METHODS = ("sql_structured", "raw_fits_prefiltered")
SLOW_KILL_METHODS = tuple(m for m in METHODS if m not in FAST_KILL_METHODS)


def _stream_drill(survey, tmp_path, method, crash):
    ref = _reference(survey, method)
    jd, out = tmp_path / "journal", tmp_path / "out.npz"
    base = ["--journal-dir", str(jd), "--out", str(out), "--method", method]
    _run_worker(base + ["--crash", crash], expect_kill=True)
    assert not out.exists()                     # it really died mid-job
    store = JournalStore(jd / "windows")
    assert store.jobs(), "no journal survived the kill"
    _run_worker(base)                           # fresh process, same journal
    coadd, depth, stats = _load_out(out)
    assert stats["resumed_windows"] >= 1
    assert stats["dispatches"] == stats["windows"] - stats["resumed_windows"]
    assert stats["jobs_left"] == []
    np.testing.assert_array_equal(coadd, np.asarray(ref.coadd))
    np.testing.assert_array_equal(depth, np.asarray(ref.depth))


@pytest.mark.parametrize("method", FAST_KILL_METHODS)
def test_sigkill_streaming_resumes_bitwise(survey, tmp_path, method):
    """SIGKILL after the first window commits; a fresh process replays it."""
    _stream_drill(survey, tmp_path, method, "manifest_done:0")


@pytest.mark.slow
@pytest.mark.parametrize("method", SLOW_KILL_METHODS)
def test_sigkill_streaming_resumes_bitwise_slow(survey, tmp_path, method):
    _stream_drill(survey, tmp_path, method, "manifest_done:0")


def test_sigkill_mid_segment_write(survey, tmp_path):
    """Death *inside* the second window's payload write: the torn tail is
    truncated on replay and only window 0 resumes from the journal."""
    _stream_drill(survey, tmp_path, "sql_structured", "payload_mid:1")


def test_sigkill_after_payload_before_manifest(survey, tmp_path):
    """Death between payload append and manifest append: the record was
    never committed, so it re-executes (atomicity of the commit point)."""
    _stream_drill(survey, tmp_path, "sql_structured", "payload_done:1")


def test_sigkill_during_materialize_resumes(survey, tmp_path):
    """SIGKILL mid-materialization: finished bricks skip, the in-flight
    brick resumes from its window journal, the mosaic stays bitwise."""
    jd, out = tmp_path / "journal", tmp_path / "out.npz"
    base = ["--journal-dir", str(jd), "--out", str(out), "--mode", "bricks"]
    _run_worker(base + ["--crash", "brick_done:1"], expect_kill=True)
    spilled = list((jd / "bricks").glob("brick-*.npz"))
    assert len(spilled) >= 1                    # at least one brick durable
    _run_worker(base)
    coadd, depth, stats = _load_out(out)
    assert stats["skipped"] >= 1
    assert stats["skipped"] + stats["completed"] == stats["n_bricks"]
    assert stats["jobs_left"] == []

    clean = tmp_path / "clean.npz"
    _run_worker(["--journal-dir", str(tmp_path / "j2"), "--out", str(clean),
                 "--mode", "bricks"])
    ref_coadd, ref_depth, _ = _load_out(clean)
    np.testing.assert_array_equal(coadd, ref_coadd)
    np.testing.assert_array_equal(depth, ref_depth)


@pytest.mark.slow
def test_sigkill_during_materialize_window_journal_resumes(survey, tmp_path):
    """Kill at a *window* commit inside some brick's streaming job: the
    restarted job must show window-journal replay (resumed_windows > 0)."""
    jd, out = tmp_path / "journal", tmp_path / "out.npz"
    base = ["--journal-dir", str(jd), "--out", str(out), "--mode", "bricks"]
    _run_worker(base + ["--crash", "manifest_done:2"], expect_kill=True)
    store = JournalStore(jd / "windows")
    assert store.jobs(), "the in-flight brick left no window journal"
    _run_worker(base)
    coadd, depth, stats = _load_out(out)
    assert stats["resumed_windows"] >= 1
    assert stats["skipped"] + stats["completed"] == stats["n_bricks"]
    clean = tmp_path / "clean.npz"
    _run_worker(["--journal-dir", str(tmp_path / "j2"), "--out", str(clean),
                 "--mode", "bricks"])
    ref_coadd, ref_depth, _ = _load_out(clean)
    np.testing.assert_array_equal(coadd, ref_coadd)
    np.testing.assert_array_equal(depth, ref_depth)


# ===== quarantine auto-release ==============================================

def test_residency_reverify_releases_repaired_packs():
    res = ResidencyManager()

    class HostDS:
        def __init__(self):
            rng = np.random.default_rng(11)
            self.pixels = rng.normal(size=(4, 2, 4, 4)).astype(np.float32)

    ds = HostDS()
    import hashlib
    digests = [hashlib.sha256(np.ascontiguousarray(ds.pixels[p]).tobytes())
               .digest() for p in range(4)]
    saved = ds.pixels[1].copy()
    ds.pixels[1, 0, 0, 0] = np.nan      # poisoned
    ds.pixels[2, 0, 0, 0] += 1.0        # finite but not the ingested bytes
    res.quarantine_packs("structured", [1, 2], digests)
    assert res.quarantined_packs("structured") == frozenset({1, 2})
    assert res.reverify_quarantined("structured", ds) == []   # nothing healed
    ds.pixels[1] = saved
    assert res.reverify_quarantined("structured", ds) == [1]  # 1 healed, 2 not
    assert res.quarantined_packs("structured") == frozenset({2})
    assert res.quarantine_released == 1
    ds.pixels[2, 0, 0, 0] -= 1.0
    assert res.reverify_quarantined("structured", ds) == [2]
    assert res.quarantine_released == 2
    assert res.quarantined == {}        # empty layouts leave the registry


def test_reverify_without_reference_digest_uses_finiteness():
    res = ResidencyManager()

    class HostDS:
        pixels = None

    ds = HostDS()
    ds.pixels = np.ones((2, 1, 2, 2), np.float32)
    ds.pixels[0, 0, 0, 0] = np.inf
    res.quarantine_packs("structured", [0, 1])   # no digests recorded
    assert res.reverify_quarantined("structured", ds) == [1]
    ds.pixels[0, 0, 0, 0] = 0.0
    assert res.reverify_quarantined("structured", ds) == [0]


def test_engine_quarantine_release_restores_full_coverage(survey):
    """End to end: real host corruption quarantines persistently across
    queries; repairing the bytes + `reverify_quarantined` releases the pack
    and the next query completes full-coverage, bitwise with clean."""
    method = "sql_structured"
    ref = _reference(survey, method)
    eng = dw.build_engine(survey, on_fault="quarantine", verify_digests=True)
    plan = eng.plan(QUERY, method)
    exec_ds, _ = eng.exec_dataset(plan.layout)
    exec_ds.pack_digests()              # prime the reference digests
    gate = eng._exec_gate(plan)
    bad = int(np.nonzero(np.asarray(gate).any(axis=1))[0][0])
    saved = exec_ds.pixels[bad].copy()
    exec_ds.pixels[bad, ...] = np.nan   # persistent host corruption

    r1 = eng.run(QUERY, method)
    assert r1.stats.partial and bad in r1.stats.uncovered_packs
    assert bad in eng.residency.quarantined_packs(plan.layout)
    r2 = eng.run(QUERY, method)         # persists: pre-gated, still partial
    assert r2.stats.partial and r2.stats.quarantined_packs == 0

    assert eng.reverify_quarantined(plan.layout) == []  # still poisoned
    exec_ds.pixels[bad] = saved                         # repair the host
    assert eng.reverify_quarantined(plan.layout) == [bad]
    assert eng.residency.quarantined_packs(plan.layout) == frozenset()

    r3 = eng.run(QUERY, method)
    assert not r3.stats.partial
    assert r3.stats.requarantine_released == 1
    assert r3.stats.uncovered_packs == ()
    np.testing.assert_array_equal(r3.coadd, ref.coadd)
    np.testing.assert_array_equal(r3.depth, ref.depth)
    r4 = eng.run(QUERY, method)
    assert r4.stats.requarantine_released == 0  # the counter is one-shot


# ===== concurrent speculation ===============================================

def _mkwin(k):
    return ScanWindow(start=k, stop=k + 1, sel=np.array([k]),
                      pack_idx=np.zeros(1, np.int32), n_gated=1, budget=1)


def test_concurrent_backup_does_not_serialize_the_run():
    """The regression the satellite demands: a straggler's backup runs on a
    worker thread, so the main loop reaches *later* windows while the
    backup is still in flight.  The backup here refuses to finish until a
    later window's primary dispatch has started — under the old serialized
    speculation this deadlocks (and times out); concurrently it passes."""
    later_started = threading.Event()
    saw = {"later_window_ran_during_backup": False}
    windows = [_mkwin(k) for k in range(4)]
    calls = {}

    def acquire(win, drop):
        return None

    def dispatch(ops, win, drop):
        n = calls.get(win.key, 0)
        calls[win.key] = n + 1
        if win.key == windows[2].key:
            later_started.set()
        if win.key == windows[1].key:
            if n == 0:
                time.sleep(0.25)        # the straggling primary
            else:
                # the backup: wait for proof the main loop moved on
                saw["later_window_ran_during_backup"] = later_started.wait(10.0)
        return (np.ones(2, np.float32),)

    tr = WindowTracker(straggler_factor=3.0, straggler_min_windows=1,
                       backoff_s=1e-4)
    acc, quar = tr.run(windows, acquire, dispatch, {})
    assert quar == []
    assert tr.counters.speculative_windows >= 1
    assert calls[windows[1].key] == 2
    assert saw["later_window_ran_during_backup"], (
        "backup thread blocked the main loop (speculation is serialized)"
    )
    np.testing.assert_array_equal(acc[0], np.full(2, 4.0, np.float32))


def test_serialized_speculation_mode_still_available():
    windows = [_mkwin(k) for k in range(3)]

    def dispatch(ops, win, drop):
        if win.key == windows[1].key:
            time.sleep(0.1)
        return (np.ones(1, np.float32),)

    tr = WindowTracker(straggler_factor=3.0, straggler_min_windows=1,
                       concurrent_speculation=False)
    acc, _ = tr.run(windows, lambda w, d: None, dispatch, {})
    assert tr.counters.speculative_windows >= 1
    assert tr._backups == []            # nothing ever went to a thread
    np.testing.assert_array_equal(acc[0], np.full(1, 3.0, np.float32))


def test_engine_speculation_concurrent_by_default_bitwise(survey):
    """Straggler speculation under the real engine (slow-window injector):
    concurrent backups keep bitwise parity and digest agreement."""
    method = "sql_structured"
    # Single-pack chunks force enough windows for a duration median.
    eng0 = dw.build_engine(survey, stream_chunk_packs=1)
    plan = eng0.plan(QUERY, method)
    exec_ds, _ = eng0.exec_dataset(plan.layout)
    gate = np.asarray(eng0._exec_gate(plan))
    n_windows = len(eng0._stream_windows(exec_ds, gate.any(axis=1)))
    assert n_windows >= 3
    ref = eng0.run(QUERY, method)
    inj = ChaosInjector(FaultSchedule(slow_windows={n_windows - 1: 0.05}))
    eng = dw.build_engine(survey, stream_chunk_packs=1, fault_injector=inj,
                          straggler_factor=3.0)
    r = eng.run(QUERY, method)
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)
    assert r.stats.speculative_windows >= 1
