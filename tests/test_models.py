"""Per-arch smoke + consistency tests (reduced configs, CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.models.model import build_model

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=12, with_labels=True, seed=3):
    key = jax.random.fold_in(RNG, seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(jax.random.fold_in(key, 2), (b, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(jax.random.fold_in(key, 3), (b, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_shapes_and_finite(arch):
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(RNG)
    batch = make_batch(cfg)
    logits, aux = m.forward(params, batch)
    assert logits.shape == (2, 12, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    cfg = reduced_config(arch)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)  # no drops
    m = build_model(cfg)
    params = m.init(RNG)
    b, s = 2, 12
    batch = make_batch(cfg, b, s + 1, with_labels=False)
    toks = batch["tokens"]
    full_logits, _ = m.forward(params, dict(batch, labels=toks))
    pre = dict(batch, tokens=toks[:, :s])
    _, cache = m.prefill(params, pre, s + 4)
    lg, _ = m.decode_step(params, cache, toks[:, s:s + 1], jnp.int32(s))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, s]),
                               atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_scan_equals_unroll(arch):
    cfg = reduced_config(arch)
    m1 = build_model(cfg)
    m2 = build_model(dataclasses.replace(cfg, scan_layers=False))
    params = m1.init(RNG)
    batch = make_batch(cfg)
    l1, _ = m1.forward(params, batch)
    l2, _ = m2.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-5)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_close_to_analytic(arch):
    cfg = get_config(arch)
    m = build_model(cfg)
    shapes = jax.eval_shape(m.init, RNG)
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / analytic < 0.06, (actual, analytic)


def test_moe_capacity_drops_are_only_train_prefill_difference():
    cfg = dataclasses.replace(reduced_config("mixtral-8x7b"), capacity_factor=100.0)
    m = build_model(cfg)
    params = m.init(RNG)
    batch = make_batch(cfg, 2, 8)
    logits, _ = m.forward(params, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_sliding_window_changes_output():
    cfg = reduced_config("mixtral-8x7b")
    m = build_model(cfg)
    params = m.init(RNG)
    batch = make_batch(cfg, 2, 16)
    l1, _ = m.forward(params, batch)
    cfg2 = dataclasses.replace(cfg, sliding_window=2)
    l2, _ = build_model(cfg2).forward(params, batch)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4
