import jax.numpy as jnp
import numpy as np

from repro.core.psf import convolve_separable, gaussian_kernel_1d, match_psf


def _gaussian_image(sigma, n=33):
    yy, xx = np.mgrid[0:n, 0:n] - (n - 1) / 2
    g = np.exp(-0.5 * (xx**2 + yy**2) / sigma**2)
    return jnp.asarray(g / g.sum(), jnp.float32)


def _measured_sigma(img):
    n = img.shape[0]
    yy, xx = np.mgrid[0:n, 0:n] - (n - 1) / 2
    img = np.asarray(img) / np.asarray(img).sum()
    return float(np.sqrt((img * (xx**2 + yy**2)).sum() / 2))


def test_kernel_normalized():
    k = gaussian_kernel_1d(1.5)
    assert abs(float(k.sum()) - 1.0) < 1e-6


def test_convolution_preserves_flux():
    img = _gaussian_image(1.0)
    out = convolve_separable(img, gaussian_kernel_1d(1.2))
    assert abs(float(out.sum()) - float(img.sum())) < 1e-4


def test_match_psf_widens_to_target():
    """Gaussian(s1) * Gaussian(sqrt(s2^2-s1^2)) = Gaussian(s2)."""
    img = _gaussian_image(1.0)
    out = match_psf(img, sigma_image=1.0, sigma_target=2.0)
    assert abs(_measured_sigma(out) - 2.0) < 0.1
    expected = _gaussian_image(2.0)
    assert float(jnp.abs(out - expected).max()) < 5e-3


def test_match_psf_noop_when_already_wider():
    img = _gaussian_image(2.0)
    out = match_psf(img, sigma_image=2.0, sigma_target=1.0)
    assert out is img
