import jax.numpy as jnp
import numpy as np

from repro.core.psf import (
    convolve_batch,
    convolve_separable,
    gaussian_kernel_1d,
    match_psf,
    matching_kernel_bank,
)


def _gaussian_image(sigma, n=33):
    yy, xx = np.mgrid[0:n, 0:n] - (n - 1) / 2
    g = np.exp(-0.5 * (xx**2 + yy**2) / sigma**2)
    return jnp.asarray(g / g.sum(), jnp.float32)


def _measured_sigma(img):
    n = img.shape[0]
    yy, xx = np.mgrid[0:n, 0:n] - (n - 1) / 2
    img = np.asarray(img) / np.asarray(img).sum()
    return float(np.sqrt((img * (xx**2 + yy**2)).sum() / 2))


def test_kernel_normalized():
    k = gaussian_kernel_1d(1.5)
    assert abs(float(k.sum()) - 1.0) < 1e-6


def test_convolution_preserves_flux():
    img = _gaussian_image(1.0)
    out = convolve_separable(img, gaussian_kernel_1d(1.2))
    assert abs(float(out.sum()) - float(img.sum())) < 1e-4


def test_match_psf_widens_to_target():
    """Gaussian(s1) * Gaussian(sqrt(s2^2-s1^2)) = Gaussian(s2)."""
    img = _gaussian_image(1.0)
    out = match_psf(img, sigma_image=1.0, sigma_target=2.0)
    assert abs(_measured_sigma(out) - 2.0) < 0.1
    expected = _gaussian_image(2.0)
    assert float(jnp.abs(out - expected).max()) < 5e-3


def test_match_psf_noop_when_already_wider():
    img = _gaussian_image(2.0)
    out = match_psf(img, sigma_image=2.0, sigma_target=1.0)
    assert out is img


def test_explicit_radius_zero_respected():
    """Regression: `radius=0` used to be silently replaced (`radius or ...`)
    by the sigma-derived default; an explicit 0 must mean a delta kernel."""
    k = gaussian_kernel_1d(1.5, radius=0)
    assert k.shape == (1,)
    assert float(k[0]) == 1.0


def test_matching_kernel_bank_closure():
    """Convolving sigma_i up to sigma_t via the bank == a direct sigma_t PSF.

    The Gaussian-closure property the engine's map stage relies on, checked
    through the exact (static-width, per-slot) bank machinery it uses.
    """
    sigmas = np.array([1.0, 1.4, 2.0], np.float32)
    target = 2.0
    bank = matching_kernel_bank(sigmas, target)
    assert bank.shape[0] == 3 and bank.ndim == 2
    np.testing.assert_allclose(bank.sum(axis=1), 1.0, atol=1e-6)
    images = jnp.stack([_gaussian_image(float(s)) for s in sigmas])
    out = convolve_batch(images, jnp.asarray(bank))
    expected = _gaussian_image(target)
    for i, s in enumerate(sigmas):
        if s >= target:
            # No-op row: already at the target width.
            np.testing.assert_allclose(out[i], images[i], atol=1e-6)
        else:
            assert abs(_measured_sigma(out[i]) - target) < 0.1
            assert float(jnp.abs(out[i] - expected).max()) < 5e-3


def test_matching_kernel_bank_all_noop_is_width_one():
    """Nothing to widen -> zero max radius -> a K=1 identity bank."""
    bank = matching_kernel_bank(np.array([2.0, 3.0]), sigma_target=1.5)
    assert bank.shape == (2, 1)
    np.testing.assert_allclose(bank, 1.0)
    # sigma <= 0 marks an empty/padded slot: it gets a delta row and must not
    # inflate the bank radius for the whole layout.
    bank0 = matching_kernel_bank(np.array([2.0, 3.0, 0.0]), sigma_target=1.5)
    assert bank0.shape == (3, 1)
    wide = matching_kernel_bank(np.array([1.0, 0.0]), sigma_target=2.0)
    r = (wide.shape[1] - 1) // 2
    np.testing.assert_allclose(wide[1], (np.arange(2 * r + 1) == r).astype(float))


def test_engine_psf_matched_parity_mapper_vs_kernel():
    """PSF-matched coadds agree between the XLA mapper path (separable
    convs) and the Pallas coadd_fused path (in-kernel banded matmuls)."""
    from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey

    sv = make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=60,
                                  height=16, width=16))
    q = CoaddQuery(band="r", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3),
                   npix=32)
    target = 2.0
    eng_m = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=target)
    eng_k = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=target,
                        use_kernel=True)
    r_m = eng_m.run(q, "sql_structured")
    r_k = eng_k.run(q, "sql_structured")
    assert r_m.depth.max() > 0
    np.testing.assert_allclose(r_k.coadd, r_m.coadd, atol=2e-2, rtol=1e-4)
    np.testing.assert_array_equal(r_k.depth, r_m.depth)
    # Matching is a real operation on this survey (per-run seeing varies):
    r_off = CoaddEngine(sv, pack_capacity=16).run(q, "sql_structured")
    assert np.abs(r_m.coadd - r_off.coadd).max() > 1e-3
    # ...but it never changes coverage, only sharpness.
    np.testing.assert_array_equal(r_m.depth, r_off.depth)
