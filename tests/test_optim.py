import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.compression import compressed_gradients, init_error
from repro.optim.schedule import warmup_cosine


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    target = jnp.array([1.0, 2.0])
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=10.0)
    state = adamw_init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_grad_clip_caps_update_norm():
    params = {"w": jnp.zeros((3,))}
    cfg = AdamWConfig(lr=1.0, grad_clip=0.5, weight_decay=0.0)
    state = adamw_init(params)
    g = {"w": jnp.array([100.0, 0.0, 0.0])}
    _, _, metrics = adamw_update(g, state, params, cfg)
    assert float(metrics["grad_norm"]) == 100.0


def test_schedule_warmup_then_decay():
    sched = warmup_cosine(10, 100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == 1.0
    assert 0.09 < float(sched(jnp.int32(100))) < 0.11
    assert float(sched(jnp.int32(55))) < 1.0


def test_error_feedback_compression_is_unbiased_over_time():
    """EF-int8 SGD tracks exact SGD on a quadratic (error feedback works)."""
    w_exact = np.array([4.0, -2.0, 1.0], np.float64)
    w_comp = w_exact.copy()
    err = init_error({"w": jnp.asarray(w_comp)})
    lr = 0.05
    for _ in range(200):
        g_exact = 2 * (w_exact - 1.0)
        w_exact -= lr * g_exact
        g = {"w": jnp.asarray(2 * (w_comp - 1.0))}
        deq, err = compressed_gradients(g, err)
        w_comp -= lr * np.asarray(deq["w"])
    np.testing.assert_allclose(w_comp, w_exact, atol=5e-2)


def test_compression_payload_is_int8():
    from repro.optim.compression import compress_tree
    g = {"a": jnp.ones((64,)) * 3.3, "b": jnp.linspace(-1, 1, 32)}
    q, s, e = compress_tree(g, jax.tree.map(jnp.zeros_like, g))
    assert all(l.dtype == jnp.int8 for l in jax.tree.leaves(q))
    deq = jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, s)
    np.testing.assert_allclose(np.asarray(deq["a"]), 3.3 * np.ones(64), rtol=0.02)
