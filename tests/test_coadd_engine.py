import numpy as np
import pytest

from repro.core import CoaddEngine, CoaddQuery, METHODS, SpatialIndex, SurveyConfig, make_survey


@pytest.fixture(scope="module")
def survey():
    return make_survey(SurveyConfig(n_runs=3, n_fields=5, n_sources=100,
                                    height=20, width=20))


@pytest.fixture(scope="module")
def engine(survey):
    return CoaddEngine(survey, pack_capacity=16)


QUERY = CoaddQuery(band="r", ra_bounds=(37.3, 37.9), dec_bounds=(-0.5, 0.3), npix=48)


def test_all_methods_agree(engine):
    results = {m: engine.run(QUERY, m) for m in METHODS if m != "raw_fits"}
    base = results["sql_structured"]
    assert base.depth.max() > 0
    for m, r in results.items():
        np.testing.assert_allclose(r.coadd, base.coadd, atol=1e-3)
        np.testing.assert_array_equal(r.depth, base.depth)


def test_depth_bounded_by_runs(engine, survey):
    r = engine.run(QUERY, "sql_structured")
    assert r.depth.max() <= survey.config.n_runs


def test_table2_structure(engine, survey):
    """Mapper-input-record orderings from the paper's Table 2."""
    stats = {m: engine.run(QUERY, m).stats for m in METHODS if m != "raw_fits"}
    coverage = stats["sql_structured"].files_contributing
    # SQL methods read exactly the relevant files (zero false positives).
    assert stats["sql_structured"].files_considered == coverage
    assert stats["sql_unstructured"].files_considered == coverage
    # Prefiltered methods read a superset (single-axis false positives)...
    assert stats["raw_fits_prefiltered"].files_considered >= coverage
    assert stats["structured_seq_prefiltered"].files_considered >= coverage
    # ...but far fewer than the full archive (the unstructured method).
    assert stats["structured_seq_prefiltered"].files_considered \
        < stats["unstructured_seq"].files_considered == len(survey)
    # Structured locality: fewer containers touched than unstructured.
    assert stats["sql_structured"].packs_touched <= stats["sql_unstructured"].packs_touched


def test_all_contributors_found(engine, survey):
    """Every method discards exactly the non-overlapping images."""
    idx = SpatialIndex.build(survey)
    exact = len(idx.select(QUERY))
    for m in ("raw_fits_prefiltered", "unstructured_seq", "sql_structured"):
        assert engine.run(QUERY, m).stats.files_contributing == exact


def test_time_bounds_query(engine):
    """Paper §6 future work: time-windowed coadds for transient studies."""
    q_all = QUERY
    q_t = CoaddQuery(band="r", ra_bounds=QUERY.ra_bounds, dec_bounds=QUERY.dec_bounds,
                     npix=48, time_bounds=(0.0, 99.0))  # first run only
    r_all = engine.run(q_all, "sql_structured")
    r_t = engine.run(q_t, "sql_structured")
    assert r_t.stats.files_contributing < r_all.stats.files_contributing
    assert r_t.depth.max() <= 1


def test_normalized_coadd_reduces_noise(engine, survey):
    """Fig. 2: stacking improves SNR — depth-normalized variance drops."""
    r = engine.run(QUERY, "sql_structured")
    deep = r.depth >= survey.config.n_runs
    if deep.sum() > 100:
        stacked = r.normalized[deep]
        assert np.isfinite(stacked).all()
