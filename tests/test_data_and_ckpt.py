import os

import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.packing import TokenShards, pack_documents, synthetic_corpus
from repro.data.pipeline import PipelineConfig, TokenPipeline


def test_packing_preserves_tokens_and_index():
    docs, srcs = synthetic_corpus(n_docs=40, vocab=128, mean_len=50, seed=1)
    shards = pack_documents(docs, srcs, shard_len=128)
    # every doc is findable at its index position
    for i, doc in enumerate(docs):
        p, o = shards.index[i]
        flat_from = shards.tokens[p].reshape(-1)[o:o + min(len(doc), 128 - o)]
        np.testing.assert_array_equal(flat_from, doc[:len(flat_from)])
    # total non-pad tokens conserved
    total_in = sum(len(d) for d in docs)
    assert (shards.doc_ids >= 0).sum() == total_in


def test_structured_shards_prune_by_source():
    docs, srcs = synthetic_corpus(n_docs=60, vocab=128, n_sources=3, seed=2)
    shards = pack_documents(docs, srcs, shard_len=128, structured=True)
    pruned = shards.prune([0])
    assert pruned.n_shards < shards.n_shards
    assert set(np.unique(pruned.source_key)) == {0}


def test_pipeline_is_deterministic_function_of_step():
    docs, srcs = synthetic_corpus(n_docs=50, vocab=64, seed=3)
    shards = pack_documents(docs, srcs, shard_len=256)
    p1 = TokenPipeline(shards, PipelineConfig(4, 32, seed=9))
    p2 = TokenPipeline(shards, PipelineConfig(4, 32, seed=9))
    for step in (0, 7, 1000):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(p1.batch_at(0)["tokens"], p1.batch_at(1)["tokens"])
    # labels are next-token targets
    b = p1.batch_at(5)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_slice_partitions_batch():
    docs, srcs = synthetic_corpus(n_docs=50, vocab=64, seed=3)
    shards = pack_documents(docs, srcs, shard_len=256)
    p = TokenPipeline(shards, PipelineConfig(8, 16, seed=0))
    b = p.batch_at(0)
    parts = [p.host_slice(b, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b["tokens"])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    state = {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                   "b": np.ones((4,), np.float32)},
        "opt": {"m": {"w": np.zeros((3, 4), np.float32)}, "step": np.int32(7)},
    }
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, state)
    assert mgr.steps() == [20, 30]  # GC'd step 10
    step, restored = mgr.restore(30, state)
    np.testing.assert_array_equal(restored["params"]["w"], state["params"]["w"])
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(5, {"params": {"x": np.ones((2,), np.float32)}})
    mgr.wait()
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert mgr.latest_step() == 5
