import numpy as np
import pytest

from repro.core.geometry import (
    WCS, image_bounds, make_grid_wcs, pixel_to_sky, sky_to_pixel,
    sky_to_tangent, tangent_to_sky,
)


def test_tangent_roundtrip():
    rng = np.random.default_rng(0)
    ra0, dec0 = 38.0, -0.3
    ra = ra0 + rng.uniform(-1, 1, 100)
    dec = dec0 + rng.uniform(-1, 1, 100)
    xi, eta = sky_to_tangent(ra, dec, ra0, dec0)
    ra2, dec2 = tangent_to_sky(xi, eta, ra0, dec0)
    np.testing.assert_allclose(ra2, ra, atol=1e-9)
    np.testing.assert_allclose(dec2, dec, atol=1e-9)


def test_pixel_sky_roundtrip():
    wcs = WCS(crval=(37.5, 0.1), crpix=(15.5, 15.5),
              cd=((0.01, 0.001), (-0.001, 0.01)))
    v = wcs.to_vector().astype(np.float64)
    x = np.linspace(0, 31, 8)
    y = np.linspace(0, 31, 8)
    ra, dec = pixel_to_sky(x, y, v)
    x2, y2 = sky_to_pixel(ra, dec, v)
    np.testing.assert_allclose(x2, x, atol=1e-6)
    np.testing.assert_allclose(y2, y, atol=1e-6)


def test_image_bounds_contains_center():
    wcs = make_grid_wcs(37.0, 0.0, 64, 0.5)
    b = image_bounds(wcs, 64, 64)
    assert b[0] < 37.0 < b[1]
    assert b[2] < 0.0 < b[3]
    assert (b[1] - b[0]) == pytest.approx(0.5, rel=0.05)


def test_grid_wcs_center_pixel():
    wcs = make_grid_wcs(40.0, -1.0, 65, 1.0)
    v = wcs.to_vector().astype(np.float64)
    ra, dec = pixel_to_sky(np.array([32.0]), np.array([32.0]), v)
    assert abs(ra[0] - 40.0) < 1e-9 and abs(dec[0] + 1.0) < 1e-9
