"""Streaming residency (DESIGN.md §6): windowed scans under a device budget.

The streaming executor — budget-sized chunk windows, LRU eviction, uploads
double-buffered behind compute — must be numerically identical to eager
whole-archive residency for every method, stay inside its byte budget even
when the archive is 4x larger, and keep the one-sync-at-reduce-time and
upload-counter contracts that make the overlap real.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core import (
    CoaddEngine,
    CoaddQuery,
    METHODS,
    ResidencyManager,
    SurveyConfig,
    make_survey,
    window_schedule,
)


@pytest.fixture(scope="module")
def survey():
    return make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=60,
                                    height=16, width=16))


QUERY = CoaddQuery(band="r", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3),
                   npix=32)
QUERY2 = CoaddQuery(band="r", ra_bounds=(37.3, 37.7), dec_bounds=(-0.4, 0.2),
                    npix=32)


def _budgeted(survey, frac=4, use_kernel=False, sparse=True, **kw):
    """A streaming engine whose budget is 1/frac of the structured layout —
    i.e. the archive is `frac`x oversubscribed relative to device memory."""
    probe = CoaddEngine(survey, pack_capacity=8)
    ds = probe.exec_dataset("structured")[0]
    budget = max(ds.chunk_nbytes(0, ds.n_packs) // frac, 1)
    return CoaddEngine(survey, pack_capacity=8, use_kernel=use_kernel,
                       sparse=sparse, device_budget_bytes=budget, **kw)


# ----- residency machinery -------------------------------------------------

def test_window_schedule_chunks_and_budgets():
    gated = np.array([0, 1, 5, 9, 10, 11])
    wins = window_schedule(gated, n_packs=12, chunk_packs=4)
    assert [(w.start, w.stop) for w in wins] == [(0, 4), (4, 8), (8, 12)]
    assert [w.n_gated for w in wins] == [2, 1, 3]
    # Budgets bucket to powers of two, capped at the chunk length.
    assert [w.budget for w in wins] == [2, 1, 4]
    # pack_idx is chunk-local; padding points at local 0.
    assert list(wins[2].pack_idx) == [1, 2, 3, 0]
    # Gap chunks produce no window; an empty gate yields one 1-pack window.
    wins = window_schedule(np.array([11]), 12, 4)
    assert [(w.start, w.stop) for w in wins] == [(8, 12)]
    empty = window_schedule(np.array([], np.int64), 12, 4)
    assert len(empty) == 1 and empty[0].budget == 1 and empty[0].n_gated == 0
    with pytest.raises(ValueError):
        window_schedule(gated, 12, 0)


def test_residency_manager_lru_eviction_order():
    log = []
    mk = lambda name: (lambda: log.append(name) or name)  # noqa: E731
    mgr = ResidencyManager(budget_bytes=100)
    assert mgr.acquire(("a",), 40, mk("a")) == "a"
    assert mgr.acquire(("b",), 40, mk("b")) == "b"
    assert mgr.bytes_resident == 80 and mgr.uploads == 2
    # Re-touch a so b becomes LRU, then force an eviction.
    assert mgr.acquire(("a",), 40, mk("a2")) == "a"   # hit: no rebuild
    assert mgr.hits == 1 and log == ["a", "b"]
    mgr.acquire(("c",), 40, mk("c"))
    assert mgr.evictions == 1 and mgr.bytes_resident == 80
    assert mgr.acquire(("a",), 40, mk("a3")) == "a"   # a survived (b evicted)
    mgr.acquire(("b",), 40, mk("b2"))                 # b must rebuild
    assert log == ["a", "b", "c", "b2"]
    # An over-budget chunk still loads (transiently exceeding the budget).
    mgr.acquire(("huge",), 500, mk("huge"))
    assert mgr.bytes_resident >= 500 and mgr.n_resident == 1
    mgr.clear()
    assert mgr.n_resident == 0 and mgr.bytes_resident == 0
    with pytest.raises(ValueError):
        ResidencyManager(budget_bytes=0)


def test_cost_aware_eviction_prefers_cheap_entries():
    """Under pressure the LRU sheds the cheapest-to-rebuild class first:
    raw chunks before matched chunks before brick tiles, regardless of
    recency; within a class, recency still decides (DESIGN.md §9)."""
    from repro.core.seqfile import (
        COST_BRICK, COST_MATCHED_CHUNK, COST_RAW_CHUNK,
    )
    mk = lambda name: (lambda: name)  # noqa: E731
    mgr = ResidencyManager(budget_bytes=300)
    # Oldest entry is the *most* expensive — plain LRU would evict it first.
    mgr.acquire(("brick", 0), 100, mk("brick"), cost=COST_BRICK)
    mgr.acquire(("raw", 0), 100, mk("raw0"), cost=COST_RAW_CHUNK)
    mgr.acquire(("raw", 1), 100, mk("raw1"), cost=COST_RAW_CHUNK)
    evicted = []
    mgr.on_evict = lambda key, entry: evicted.append(key)
    # Touch raw0 so it is *more* recent than raw1; cheapest class evicts in
    # its own LRU order: raw1 first, then raw0, and the brick survives both.
    mgr.acquire(("raw", 1), 100, mk("raw1-again"))
    mgr.acquire(("matched", 0), 100, mk("m0"), cost=COST_MATCHED_CHUNK)
    assert evicted == [("raw", 0)]
    mgr.acquire(("matched", 1), 100, mk("m1"), cost=COST_MATCHED_CHUNK)
    assert evicted == [("raw", 0), ("raw", 1)]
    # Only matched + brick left; matched is now the cheapest class.
    mgr.acquire(("raw", 2), 100, mk("raw2"), cost=COST_RAW_CHUNK)
    assert evicted == [("raw", 0), ("raw", 1), ("matched", 0)]
    assert mgr.resident(("brick", 0))  # most expensive entry outlived all
    # Uniform costs degrade to plain LRU (pinned by the test above).


# ----- parity: streaming == eager ------------------------------------------

@pytest.mark.parametrize("method", [m for m in METHODS])
def test_streaming_matches_eager_4x_oversubscribed(survey, method):
    """An archive 4x the device budget coadds identically to eager residency."""
    eager = CoaddEngine(survey, pack_capacity=8)
    stream = _budgeted(survey, frac=4)
    re = eager.run(QUERY, method)
    rs = stream.run(QUERY, method)
    assert re.depth.max() > 0
    np.testing.assert_allclose(rs.coadd, re.coadd, atol=5e-2, rtol=1e-3)
    np.testing.assert_array_equal(rs.depth, re.depth)
    assert rs.stats.files_considered == re.stats.files_considered
    assert rs.stats.files_contributing == re.stats.files_contributing
    # Streaming accounting: one dispatch per window, budget respected.
    assert rs.stats.windows >= 1
    assert rs.stats.dispatches == rs.stats.windows
    assert rs.stats.chunk_uploads <= rs.stats.windows
    assert stream.residency.bytes_resident <= stream.device_budget_bytes


@pytest.mark.parametrize("use_kernel", [False, True], ids=["xla", "kernel"])
def test_streaming_matches_eager_with_kernel(survey, use_kernel):
    eager = CoaddEngine(survey, pack_capacity=8, use_kernel=use_kernel)
    stream = _budgeted(survey, frac=4, use_kernel=use_kernel)
    for method in ("sql_structured", "raw_fits_prefiltered"):
        re = eager.run(QUERY, method)
        rs = stream.run(QUERY, method)
        np.testing.assert_allclose(rs.coadd, re.coadd, atol=5e-2, rtol=1e-3)
        np.testing.assert_array_equal(rs.depth, re.depth)


def test_streaming_dense_scan_matches(survey):
    """sparse=False + budget: the dense semantics stream over every pack."""
    eager = CoaddEngine(survey, pack_capacity=8, sparse=False)
    stream = _budgeted(survey, frac=4, sparse=False)
    re = eager.run(QUERY, "sql_structured")
    rs = stream.run(QUERY, "sql_structured")
    np.testing.assert_allclose(rs.coadd, re.coadd, atol=5e-2, rtol=1e-3)
    np.testing.assert_array_equal(rs.depth, re.depth)
    ds = stream.exec_dataset("structured")[0]
    assert rs.stats.packs_scanned == ds.n_packs  # dense: everything scans


def test_streaming_batch_matches_eager(survey):
    eager = CoaddEngine(survey, pack_capacity=8)
    stream = _budgeted(survey, frac=4)
    before = stream.dispatch_count
    ea = eager.run_batch([QUERY, QUERY2], "sql_structured")
    st = stream.run_batch([QUERY, QUERY2], "sql_structured")
    for a, b in zip(ea, st):
        np.testing.assert_allclose(b.coadd, a.coadd, atol=5e-2, rtol=1e-3)
        np.testing.assert_array_equal(b.depth, a.depth)
        assert b.stats.files_considered == a.stats.files_considered
        assert b.stats.files_contributing == a.stats.files_contributing
    # One dispatch per window for the whole batch, attributed to result 0.
    assert stream.dispatch_count - before == st[0].stats.windows
    assert st[1].stats.dispatches == 0 and st[1].stats.packs_scanned == 0


def test_streaming_empty_gate(survey):
    """Empty selections answer zeros with NO window schedule at all: no
    upload, no dispatch, and no window-stat reduction over an empty list
    (the max()-over-budgets guard)."""
    stream = _budgeted(survey, frac=4)
    far = CoaddQuery(band="r", ra_bounds=(200.0, 201.0),
                     dec_bounds=(50.0, 51.0), npix=32)
    r = stream.run(far, "sql_structured")
    assert np.all(r.coadd == 0) and np.all(r.depth == 0)
    assert not np.isnan(r.normalized).any()
    assert r.stats.windows == 0 and r.stats.scan_budget == 0
    assert r.stats.dispatches == 0 and r.stats.chunk_uploads == 0
    assert r.stats.files_considered == 0


def test_streaming_empty_gate_batch(survey):
    """The batched streaming executor keeps the same empty-union contract."""
    stream = _budgeted(survey, frac=4)
    far = CoaddQuery(band="r", ra_bounds=(200.0, 201.0),
                     dec_bounds=(50.0, 51.0), npix=32)
    results = stream.run_batch([far, far], "sql_structured")
    for r in results:
        assert np.all(r.coadd == 0) and np.all(r.depth == 0)
        assert r.stats.windows == 0 and r.stats.dispatches == 0
        assert r.stats.chunk_uploads == 0


# ----- eviction correctness -------------------------------------------------

def test_eviction_under_budget_smaller_than_layout(survey):
    """Repeated mixed queries under a tight budget force evictions without
    ever corrupting results or exceeding the budget."""
    eager = CoaddEngine(survey, pack_capacity=8)
    stream = _budgeted(survey, frac=4)
    total_evictions = 0
    for q, m in [(QUERY, "sql_structured"), (QUERY2, "unstructured_seq"),
                 (QUERY, "raw_fits_prefiltered"), (QUERY2, "sql_structured"),
                 (QUERY, "sql_structured")]:
        re = eager.run(q, m)
        rs = stream.run(q, m)
        np.testing.assert_allclose(rs.coadd, re.coadd, atol=5e-2, rtol=1e-3)
        np.testing.assert_array_equal(rs.depth, re.depth)
        total_evictions += rs.stats.residency_evictions
        assert stream.residency.bytes_resident <= stream.device_budget_bytes
    # Three layouts through a quarter-layout budget must have evicted.
    assert total_evictions > 0


# ----- upload/compute overlap ----------------------------------------------

def test_repeat_query_hits_residency_no_reupload(survey):
    """With the working set inside the budget, a repeat query uploads zero
    chunks — the upload counter is the §3 residency contract, per chunk."""
    probe = CoaddEngine(survey, pack_capacity=8)
    ds = probe.exec_dataset("structured")[0]
    total = ds.chunk_nbytes(0, ds.n_packs)
    # Budget holds the whole layout, but small chunks force many windows.
    stream = CoaddEngine(survey, pack_capacity=8,
                         device_budget_bytes=2 * total, stream_chunk_packs=4)
    r1 = stream.run(QUERY, "unstructured_seq")   # gates every pack
    assert r1.stats.windows > 1
    assert r1.stats.chunk_uploads == r1.stats.windows  # cold: all misses
    uploads = stream.pack_upload_count
    r2 = stream.run(QUERY, "unstructured_seq")
    assert r2.stats.chunk_uploads == 0                 # warm: all hits
    assert r2.stats.residency_hits == r2.stats.windows
    assert r2.stats.residency_evictions == 0
    assert stream.pack_upload_count == uploads


def test_streaming_blocks_only_at_reduce_time(survey, monkeypatch):
    """The overlap regression: a multi-window query must issue every window
    dispatch and chunk upload before its single host sync (`engine._sync`).
    A sync per window would serialize uploads against compute and forfeit
    the double buffering."""
    stream = _budgeted(survey, frac=4, stream_chunk_packs=2)
    syncs = []
    real_sync = engine_mod._sync
    monkeypatch.setattr(engine_mod, "_sync",
                        lambda x: syncs.append(1) or real_sync(x))
    r = stream.run(QUERY, "sql_structured")
    assert r.stats.windows > 1          # non-trivial: actually windowed
    assert len(syncs) == 1              # one sync for the whole query
    syncs.clear()
    stream.run_batch([QUERY, QUERY2], "sql_structured")
    assert len(syncs) == 1


# ----- distributed streaming + per-shard budgets ----------------------------

def test_distributed_streaming_matches_eager(survey):
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eager = CoaddEngine(survey, pack_capacity=8)
    stream = _budgeted(survey, frac=4)
    rd = eager.run_distributed([QUERY, QUERY2], mesh)
    rs = stream.run_distributed([QUERY, QUERY2], mesh)
    for a, b in zip(rd, rs):
        assert a.depth.max() > 0
        np.testing.assert_allclose(b.coadd, a.coadd, atol=1e-2, rtol=1e-4)
        np.testing.assert_array_equal(b.depth, a.depth)
    assert rs[0].stats.windows > 1
    assert rs[0].stats.dispatches == rs[0].stats.windows
    # Mesh windows upload through the same LRU: a repeat job inside the
    # budget's working set re-uploads at most what eviction dropped.
    assert stream.mesh_upload_count == rs[0].stats.chunk_uploads
    # And the single-host answer agrees.
    ref = stream.run(QUERY, "sql_structured")
    np.testing.assert_allclose(rs[0].coadd, ref.coadd, atol=1e-2, rtol=1e-4)


@pytest.mark.slow
def test_distributed_streaming_and_shard_budgets_8dev():
    """Real 8-shard mesh: streaming windows + per-shard budget tile loop
    reproduce the eager dense answer on a skewed (band-gated) selection."""
    import os
    import subprocess
    import sys
    import textwrap

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = textwrap.dedent('''
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey
        sv = make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=60,
                                      height=16, width=16))
        q = CoaddQuery(band="r", ra_bounds=(37.2, 37.8),
                       dec_bounds=(-0.5, 0.3), npix=32)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        eager = CoaddEngine(sv, pack_capacity=16)
        ds = eager.exec_dataset("structured")[0]
        budget = max(ds.chunk_nbytes(0, ds.n_packs) // 4, 1)
        stream = CoaddEngine(sv, pack_capacity=16, device_budget_bytes=budget)
        rd = eager.run_distributed([q], mesh)[0]
        rs = stream.run_distributed([q], mesh)[0]
        assert rd.depth.max() > 0
        assert np.abs(rs.coadd - rd.coadd).max() < 1e-2
        assert np.array_equal(rs.depth, rd.depth)
        # Per-shard budgets: a band-gated selection is skewed across the
        # flat shards, so the summed per-shard buckets must undercut the
        # old worst-shard-times-n_shards accounting.
        from repro.distributed.sharding import shard_local_compaction
        gates = ds.flat_slot_mask(eager.sql.select(q), pad_to=ds.flat_len(8))
        idx, mask, shared, budgets = shard_local_compaction(gates, 8)
        assert budgets.shape == (8,) and budgets.max() == shared
        # Band-gated selections are skewed across flat shards: the quiet
        # shards' own buckets must undercut the shared worst-shard bucket.
        assert budgets.min() < budgets.max(), budgets
        assert int(budgets.sum()) < 8 * shared, (budgets, shared)
        print("OK")
    ''')
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "OK" in r.stdout


# ----- true peak-residency accounting (ISSUE 5 satellite) -------------------


def test_peak_residency_pinned_under_4x_oversubscription(survey):
    """The ROADMAP eviction-accounting fix: `ResidencyManager.peak_bytes`
    must report the *true* high-water mark — budget + one in-flight
    window's operands — not the advisory budget, and stats must surface it.
    Pinned at 4x oversubscription where eviction churn is guaranteed."""
    stream = _budgeted(survey, frac=4)
    r = stream.run(QUERY, "structured_seq_prefiltered")
    assert r.stats.residency_evictions > 0 or r.stats.windows >= 2
    peak = stream.residency.peak_bytes
    assert r.stats.peak_resident_bytes == peak
    assert peak > 0
    # One window's operands = the largest chunk ever resident.
    ds = stream.exec_dataset("structured")[0]
    chunk_bytes = ds.chunk_nbytes(0, stream._chunk_packs(ds))
    assert peak <= stream.device_budget_bytes + chunk_bytes, (
        peak, stream.device_budget_bytes, chunk_bytes)


def test_peak_residency_counts_in_flight_eviction():
    """Unit-level: evicting the entry a consumer is still scanning must
    charge its bytes to the peak (budget + one window), while evicting a
    cold entry must not."""
    # Cold eviction first: the LRU victim is NOT the last-served entry, so
    # its buffers are genuinely free — peak stays at the budget.
    mgr = ResidencyManager(budget_bytes=100)
    mgr.acquire(("a",), 50, lambda: "A")
    mgr.acquire(("b",), 50, lambda: "B")      # resident a+b = 100, b in flight
    mgr.acquire(("c",), 50, lambda: "C")      # evicts a (cold) -> b+c = 100
    assert mgr.evictions == 1
    assert mgr.peak_bytes == 100
    # In-flight eviction: inserting d(100) evicts b (cold) then c — and c
    # is the last-served entry a scan may still hold, so its 50 bytes ride
    # on top of the resident 100: budget + one window's operands.
    mgr.acquire(("d",), 100, lambda: "D")
    assert mgr.evictions == 3
    assert mgr.peak_bytes == 100 + 50
    # Declared build-time transients (e.g. the raw chunk a matched-pixel
    # build convolves from) join the peak candidate too.
    mgr.acquire(("e",), 100, lambda: "E", transient_bytes=30)
    assert mgr.peak_bytes == 100 + 100 + 30  # e + in-flight d + transient


def test_peak_residency_includes_matched_cache(survey):
    """Derived matched-pixel entries are budget bytes too: the eager
    matched cache must appear in peak accounting without any H2D upload —
    and the reported peak must count BOTH copies (raw resident layout +
    matched derivative), the true eager footprint."""
    eng = CoaddEngine(survey, pack_capacity=8, match_psf_sigma=2.0)
    r = eng.run(QUERY, "sql_structured")
    dev = eng.device_dataset("structured")
    assert eng.residency.peak_bytes >= int(dev.pixels.nbytes)
    assert eng.residency.uploads == 0          # derived, not uploaded
    assert eng.residency.derived_builds == 1
    # raw pixels (unmanaged eager upload) + matched pixels (managed entry)
    assert r.stats.peak_resident_bytes >= 2 * int(dev.pixels.nbytes)
