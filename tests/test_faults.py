"""Chaos drills for the streaming fault domain (DESIGN.md §8).

The paper's §3 premise — failures are the norm — demands that a streaming
query survive upload failures, poisoned inputs, stragglers, and mid-query
kills without changing its answer.  Every drill here injects deterministic
faults at the engine's real seams (`ChaosInjector`) on a 4x-oversubscribed
archive and asserts *bitwise* parity with the fault-free run whenever
``on_fault="retry"`` heals, exact accounting when ``"quarantine"`` completes
partial, and journal-replay-only resumption after a kill.
"""
import numpy as np
import pytest

from repro.core import (
    ChaosInjector,
    CoaddEngine,
    CoaddQuery,
    DeterminismError,
    FaultSchedule,
    METHODS,
    PoisonSpec,
    PoisonedChunkError,
    QueryKilled,
    ResidencyManager,
    SurveyConfig,
    TransientFault,
    WindowTracker,
    classify,
    make_survey,
    window_schedule,
)
from repro.core.jobtracker import partial_digest


@pytest.fixture(scope="module")
def survey():
    return make_survey(SurveyConfig(n_runs=2, n_fields=4, n_sources=60,
                                    height=16, width=16))


QUERY = CoaddQuery(band="r", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3),
                   npix=32)

# Fault-free streaming results, shared across the matrix: one per
# (method, chunk_packs).  Parity must be bitwise — clean and faulted runs
# execute the identical jitted programs in the identical window order.
_REFS = {}


def _chaos(survey, injector=None, chunk_packs=2, **kw):
    """A 4x-oversubscribed streaming engine with fast-backoff fault handling."""
    probe = CoaddEngine(survey, pack_capacity=8)
    ds = probe.exec_dataset("structured")[0]
    budget = max(ds.chunk_nbytes(0, ds.n_packs) // 4, 1)
    return CoaddEngine(survey, pack_capacity=8, device_budget_bytes=budget,
                       stream_chunk_packs=chunk_packs, fault_backoff_s=1e-4,
                       fault_injector=injector, **kw)


def _reference(survey, method, chunk_packs=2):
    key = (method, chunk_packs)
    if key not in _REFS:
        _REFS[key] = _chaos(survey, chunk_packs=chunk_packs).run(QUERY, method)
    return _REFS[key]


def _query_shape(survey, method, chunk_packs=2):
    """(gated global packs, n_windows) of the clean query, for fault aiming."""
    eng = _chaos(survey, chunk_packs=chunk_packs)
    plan = eng.plan(QUERY, method)
    gate = eng._exec_gate(plan)
    exec_ds, _ = eng.exec_dataset(plan.layout)
    windows = eng._stream_windows(exec_ds, gate.any(axis=1))
    return np.nonzero(gate.any(axis=1))[0], len(windows)


# ----- the 6-method chaos matrix -------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_upload_failure_retries_to_bitwise_parity(survey, method):
    ref = _reference(survey, method)
    inj = ChaosInjector(FaultSchedule(upload_fail_ordinals=(0,)))
    r = _chaos(survey, injector=inj).run(QUERY, method)
    assert inj.injected["upload_fail"] == 1
    assert r.stats.retries >= 1
    assert not r.stats.partial
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)


@pytest.mark.parametrize("method", METHODS)
def test_poisoned_chunk_retries_to_bitwise_parity(survey, method):
    ref = _reference(survey, method)
    packs, _ = _query_shape(survey, method)
    inj = ChaosInjector(FaultSchedule(
        poison=(PoisonSpec(pack=int(packs[0]), mode="nan", count=1),)
    ))
    r = _chaos(survey, injector=inj).run(QUERY, method)
    assert inj.injected["poison"] >= 1
    assert r.stats.retries >= 1
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)


@pytest.mark.parametrize("method", METHODS)
def test_straggler_speculation_bitwise_parity(survey, method):
    # Single-pack chunks force enough windows for a duration median.
    ref = _reference(survey, method, chunk_packs=1)
    _, n_windows = _query_shape(survey, method, chunk_packs=1)
    assert n_windows >= 3
    inj = ChaosInjector(FaultSchedule(slow_windows={n_windows - 1: 0.05}))
    r = _chaos(survey, injector=inj, chunk_packs=1,
               straggler_factor=3.0).run(QUERY, method)
    assert inj.injected["slow"] == 1
    assert r.stats.speculative_windows >= 1
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)


@pytest.mark.parametrize("method", METHODS)
def test_kill_and_resume_replays_only_missing_windows(survey, method):
    ref = _reference(survey, method)
    _, n_windows = _query_shape(survey, method)
    assert n_windows >= 2
    inj = ChaosInjector(FaultSchedule(kill_after_windows=1))
    eng = _chaos(survey, injector=inj)
    with pytest.raises(QueryKilled):
        eng.run(QUERY, method)
    assert len(eng._journals) == 1  # the killed query's journal survives
    r = eng.run(QUERY, method)      # injector fired once; resume runs clean
    # Journal-hit accounting: exactly the windows finished before the kill
    # replay from the journal, the rest re-execute.
    assert r.stats.resumed_windows == 1
    assert r.stats.dispatches == n_windows - 1
    assert len(eng._journals) == 0  # completion retires the journal
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)


# ----- quarantine accounting -----------------------------------------------

def test_quarantine_completes_partial_with_correct_depth(survey):
    method = "sql_structured"
    packs, _ = _query_shape(survey, method)
    bad = int(packs[0])
    inj = ChaosInjector(FaultSchedule(
        poison=(PoisonSpec(pack=bad, mode="nan", count=None),)  # persistent
    ))
    r = _chaos(survey, injector=inj, on_fault="quarantine").run(QUERY, method)
    assert r.stats.partial
    assert r.stats.uncovered_packs == (bad,)
    assert r.stats.quarantined_packs == 1
    assert np.isfinite(r.coadd).all() and np.isfinite(r.depth).all()

    # Ground truth: the same query with the quarantined pack's slots gated
    # off at plan time (sql_structured plans on the execution layout, so
    # plan-gate packs == exec-gate packs).
    eng = _chaos(survey)
    plan = eng.plan(QUERY, method)
    plan.gate[bad] = False
    clean = eng.execute(plan)
    np.testing.assert_allclose(r.coadd, clean.coadd, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(r.depth, clean.depth)
    assert r.stats.files_contributing == clean.stats.files_contributing


def test_persistent_poison_exhausts_retry_policy(survey):
    packs, _ = _query_shape(survey, "sql_structured")
    inj = ChaosInjector(FaultSchedule(
        poison=(PoisonSpec(pack=int(packs[0]), mode="nan", count=None),)
    ))
    with pytest.raises(PoisonedChunkError):
        _chaos(survey, injector=inj, on_fault="retry").run(QUERY, "sql_structured")


def test_raise_policy_aborts_on_first_fault(survey):
    inj = ChaosInjector(FaultSchedule(upload_fail_ordinals=(0,)))
    with pytest.raises(TransientFault):
        _chaos(survey, injector=inj, on_fault="raise").run(QUERY, "sql_structured")


def test_digest_verification_catches_finite_corruption(survey):
    """mode="flip" corruption is finite — invisible to the NaN scan, caught
    only by the per-pack digest comparison against the host seqfile."""
    method = "sql_structured"
    ref = _reference(survey, method)
    packs, _ = _query_shape(survey, method)
    spec = PoisonSpec(pack=int(packs[0]), mode="flip", count=1)
    # Without digests the corruption sails through (and corrupts the coadd).
    r_blind = _chaos(
        survey, injector=ChaosInjector(FaultSchedule(poison=(spec,)))
    ).run(QUERY, method)
    assert r_blind.stats.retries == 0
    # With digests it's detected, retried, and healed to bitwise parity.
    r = _chaos(
        survey, injector=ChaosInjector(FaultSchedule(poison=(spec,))),
        verify_digests=True,
    ).run(QUERY, method)
    assert r.stats.retries >= 1
    np.testing.assert_array_equal(r.coadd, ref.coadd)


# ----- batched streaming under faults --------------------------------------

def test_batch_streaming_heals_upload_failure(survey):
    q2 = CoaddQuery(band="g", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3),
                    npix=32)
    clean = _chaos(survey).run_batch([QUERY, q2], "sql_structured")
    inj = ChaosInjector(FaultSchedule(upload_fail_ordinals=(0,)))
    faulted = _chaos(survey, injector=inj).run_batch([QUERY, q2],
                                                     "sql_structured")
    assert faulted[0].stats.retries >= 1
    for c, f in zip(clean, faulted):
        np.testing.assert_array_equal(c.coadd, f.coadd)
        np.testing.assert_array_equal(c.depth, f.depth)


# ----- robust multi-pass fault domain (DESIGN.md §11) -----------------------

ROBUST = ("clipped", "median")
_ROBUST_REFS = {}


def _robust_reference(survey, red):
    """Fault-free streaming robust stack, shared across the robust matrix."""
    if red not in _ROBUST_REFS:
        _ROBUST_REFS[red] = _chaos(survey).run(QUERY, "sql_structured",
                                               reduce=red)
    return _ROBUST_REFS[red]


@pytest.mark.parametrize("red", ROBUST)
def test_robust_midpass_kill_replays_partial_journal_bitwise(survey, red):
    """A kill inside pass 1 leaves a partial pass-1 journal; the resume
    replays exactly the finished window and reproduces the uninterrupted
    robust stack bitwise."""
    ref = _robust_reference(survey, red)
    _, n_windows = _query_shape(survey, "sql_structured")
    assert n_windows >= 2
    inj = ChaosInjector(FaultSchedule(kill_after_windows=1))
    eng = _chaos(survey, injector=inj)
    with pytest.raises(QueryKilled):
        eng.run(QUERY, "sql_structured", reduce=red)
    assert len(eng._journals) == 1      # the killed pass's journal survives
    r = eng.run(QUERY, "sql_structured", reduce=red)
    assert r.stats.resumed_windows == 1  # only the finished window replays
    assert r.stats.reduce_passes == (3 if red == "median" else 2)
    assert len(eng._journals) == 0      # completion retires every pass journal
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)


@pytest.mark.parametrize("red", ROBUST)
def test_robust_seam_kill_resumes_without_rerunning_pass1(survey, red):
    """A kill at the pass-1/pass-2 seam (every pass-1 window journaled,
    no later pass started) must resume by replaying ALL of pass 1 from the
    journal — zero re-executed pass-1 windows — and still match bitwise."""
    ref = _robust_reference(survey, red)
    _, n_windows = _query_shape(survey, "sql_structured")
    inj = ChaosInjector(FaultSchedule(kill_after_windows=n_windows))
    eng = _chaos(survey, injector=inj)
    with pytest.raises(QueryKilled):
        eng.run(QUERY, "sql_structured", reduce=red)
    assert len(eng._journals) == 1
    r = eng.run(QUERY, "sql_structured", reduce=red)
    assert r.stats.resumed_windows == n_windows  # pass 1 replayed, not rerun
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)


@pytest.mark.parametrize("red", ROBUST)
def test_robust_upload_failure_retries_to_bitwise_parity(survey, red):
    ref = _robust_reference(survey, red)
    inj = ChaosInjector(FaultSchedule(upload_fail_ordinals=(0,)))
    r = _chaos(survey, injector=inj).run(QUERY, "sql_structured", reduce=red)
    assert inj.injected["upload_fail"] == 1
    assert r.stats.retries >= 1
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)


@pytest.mark.parametrize("red", ROBUST)
def test_robust_quarantine_excludes_pack_from_every_pass(survey, red):
    """A persistently poisoned pack quarantined during pass 1 must stay
    excluded through the clip pass: the answer equals the clean robust run
    with that pack gated off at plan time (any pass disagreeing about the
    sample set would shift depth by whole coverage units)."""
    method = "sql_structured"
    packs, _ = _query_shape(survey, method)
    bad = int(packs[0])
    inj = ChaosInjector(FaultSchedule(
        poison=(PoisonSpec(pack=bad, mode="nan", count=None),)
    ))
    r = _chaos(survey, injector=inj, on_fault="quarantine").run(
        QUERY, method, reduce=red)
    assert r.stats.partial
    assert r.stats.uncovered_packs == (bad,)
    assert r.stats.quarantined_packs >= 1
    assert np.isfinite(r.coadd).all() and np.isfinite(r.depth).all()

    eng = _chaos(survey)
    plan = eng.plan(QUERY, method, reduce=red)
    plan.gate[bad] = False
    clean = eng.execute(plan)
    np.testing.assert_array_equal(r.depth, clean.depth)
    np.testing.assert_allclose(r.coadd, clean.coadd, rtol=1e-5, atol=1e-5)


# ----- the seeded acceptance drill -----------------------------------------

def test_seeded_chaos_drill_all_faults_at_once(survey):
    """The acceptance drill: a seeded schedule lands >=1 upload failure,
    >=1 poisoned chunk, and >=1 straggler in ONE 4x-oversubscribed query;
    retry+speculation reproduce the fault-free coadd bitwise."""
    method = "sql_structured"
    ref = _reference(survey, method, chunk_packs=1)
    packs, n_windows = _query_shape(survey, method, chunk_packs=1)
    sched = FaultSchedule.seeded(
        seed=82, n_uploads=n_windows, n_windows=n_windows, gated_packs=packs,
        upload_fails=1, poisons=1, stragglers=1, slow_s=0.05,
    )
    inj = ChaosInjector(sched)
    r = _chaos(survey, injector=inj, chunk_packs=1,
               straggler_factor=3.0).run(QUERY, method)
    assert inj.injected["upload_fail"] >= 1
    assert inj.injected["poison"] >= 1
    assert inj.injected["slow"] >= 1
    assert r.stats.retries >= 2  # the upload failure and the poison
    np.testing.assert_array_equal(r.coadd, ref.coadd)
    np.testing.assert_array_equal(r.depth, ref.depth)


def test_seeded_schedule_is_deterministic():
    packs = np.arange(12)
    a = FaultSchedule.seeded(seed=7, n_uploads=6, n_windows=6,
                             gated_packs=packs)
    b = FaultSchedule.seeded(seed=7, n_uploads=6, n_windows=6,
                             gated_packs=packs)
    assert a == b
    c = FaultSchedule.seeded(seed=8, n_uploads=6, n_windows=6,
                             gated_packs=packs)
    assert a != c


# ----- unit-level tracker/harness behavior ---------------------------------

class _FakeWin:
    def __init__(self, i):
        self.key = (i, i + 1, 1, 1)


def test_window_tracker_backoff_is_capped_exponential():
    sleeps = []
    tr = WindowTracker(backoff_s=0.1, backoff_cap_s=0.35, max_attempts=5,
                       sleep=sleeps.append)
    calls = [0]

    def acquire(win, quarantined):
        calls[0] += 1
        if calls[0] < 5:
            raise TransientFault("flaky")
        return "ops"

    out, quar = tr.run([_FakeWin(0)], acquire,
                       lambda ops, win, q: (np.ones(2),), {})
    assert sleeps == [0.1, 0.2, 0.35, 0.35]  # doubling, then capped
    assert tr.counters.retries == 4
    assert quar == []


def test_window_tracker_speculation_flags_nondeterminism():
    tr = WindowTracker(straggler_factor=1.5, straggler_min_windows=1)
    rng = np.random.default_rng(0)

    def dispatch(ops, win, quarantined):
        import time as _t
        if win.key[0] == 2:
            _t.sleep(0.05)  # the straggler: its backup re-rolls the dice
        return (rng.normal(size=4),)  # nondeterministic executor

    wins = [_FakeWin(i) for i in range(3)]
    with pytest.raises(DeterminismError):
        tr.run(wins, lambda w, q: "ops", dispatch, {})
    assert tr.counters.speculative_windows == 1


def test_window_tracker_fatal_errors_escape_immediately():
    tr = WindowTracker(max_attempts=5)
    attempts = [0]

    def acquire(win, quarantined):
        attempts[0] += 1
        raise ValueError("fatal config error")

    with pytest.raises(ValueError):
        tr.run([_FakeWin(0)], acquire, lambda o, w, q: (np.zeros(1),), {})
    assert attempts[0] == 1  # no retry net around fatal errors
    assert tr.counters.retries == 0


def test_classification_taxonomy():
    assert classify(TransientFault("x")) == "transient"
    assert classify(ConnectionError("x")) == "transient"
    assert classify(OSError("x")) == "transient"
    assert classify(RuntimeError("xla")) == "transient"  # XLA policy
    assert classify(PoisonedChunkError([3])) == "transient"
    assert classify(DeterminismError("x")) == "fatal"
    assert classify(QueryKilled("x")) == "fatal"
    assert classify(ValueError("x")) == "fatal"
    assert classify(KeyError("x")) == "fatal"


def test_partial_digest_distinguishes_content():
    a = (np.ones((4, 4)), np.zeros(3))
    b = (np.ones((4, 4)), np.zeros(3))
    c = (np.ones((4, 4)) * 2, np.zeros(3))
    assert partial_digest(a) == partial_digest(b)
    assert partial_digest(a) != partial_digest(c)


def test_residency_failed_build_leaves_manager_consistent():
    mgr = ResidencyManager(budget_bytes=100)
    mgr.acquire(("a",), 40, lambda: "A")
    with pytest.raises(TransientFault):
        mgr.acquire(("b",), 40, lambda: (_ for _ in ()).throw(
            TransientFault("upload lost")))
    assert mgr.failed_builds == 1
    assert mgr.n_resident == 1          # no phantom entry
    assert mgr.uploads == 1             # failed build never counted
    # Retry succeeds and the manager looks like the failure never happened.
    assert mgr.acquire(("b",), 40, lambda: "B") == "B"
    assert mgr.n_resident == 2 and mgr.uploads == 2


def test_residency_fault_hook_failure_counts_and_propagates():
    mgr = ResidencyManager(budget_bytes=100)
    fired = []

    def hook(key):
        fired.append(key)
        raise TransientFault("injected")

    mgr.fault_hook = hook
    with pytest.raises(TransientFault):
        mgr.acquire(("k",), 10, lambda: "payload")
    assert fired == [("k",)]
    assert mgr.failed_builds == 1 and mgr.n_resident == 0
    mgr.fault_hook = None
    assert mgr.acquire(("k",), 10, lambda: "payload") == "payload"


def test_scan_window_key_is_schedule_unique():
    wins = window_schedule(np.array([0, 1, 5, 9, 10, 11]), 12, 4)
    keys = [w.key for w in wins]
    assert len(set(keys)) == len(keys)
