"""Difference imaging + source detection (DESIGN.md §11 acceptance).

The drill the subsystem exists for: seeded transients injected into the
newest epoch must be recovered from the epoch-minus-template difference
at 5 sigma — >= 95% of them, with ZERO spurious detections — and the
same pipeline over an un-injected survey must find nothing at all.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    CoaddEngine,
    CoaddQuery,
    SurveyConfig,
    detect_sources,
    difference_image,
    epoch_time_bounds,
    inject_transients,
    make_survey,
    match_detections,
)
from repro.core.detect import sky_to_grid

CFG = SurveyConfig(n_runs=3, n_fields=5, n_sources=100, height=20, width=20)
QUERY = CoaddQuery(band="r", ra_bounds=(37.3, 37.9), dec_bounds=(-0.5, 0.3),
                   npix=48)


@pytest.fixture(scope="module")
def injected():
    """(engine, truths): survey with 8 seeded transients in the last run."""
    sv = make_survey(CFG)
    truths = inject_transients(sv, QUERY, n=8, flux=400.0, seed=7)
    eng = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.0)
    return eng, truths


@pytest.fixture(scope="module")
def static_engine():
    return CoaddEngine(make_survey(CFG), pack_capacity=16,
                       match_psf_sigma=2.0)


def test_epoch_time_bounds():
    sv = make_survey(SurveyConfig(n_runs=3, n_fields=2, n_sources=10,
                                  height=12, width=12))
    assert epoch_time_bounds(sv) == (200.0, 299.0)      # default: last run
    assert epoch_time_bounds(sv, run=0) == (0.0, 99.0)


def test_injection_is_seeded_and_separated():
    sv_a, sv_b = make_survey(CFG), make_survey(CFG)
    ta = inject_transients(sv_a, QUERY, n=8, seed=7)
    tb = inject_transients(sv_b, QUERY, n=8, seed=7)
    np.testing.assert_array_equal(ta, tb)               # same seed, same sky
    xa, ya = sky_to_grid(QUERY, ta[:, 0], ta[:, 1])
    d2 = (xa[:, None] - xa) ** 2 + (ya[:, None] - ya) ** 2
    np.fill_diagonal(d2, np.inf)
    assert d2.min() >= 6.0 ** 2                         # pairwise min_sep_px
    # An impossible placement request fails loudly, not by under-injecting.
    with pytest.raises(ValueError):
        inject_transients(make_survey(CFG), QUERY, n=40, min_sep_px=50.0)


def test_recovers_95pct_with_zero_false_positives(injected):
    eng, truths = injected
    diff, d_epoch, d_tmpl = difference_image(eng, QUERY, reduce="clipped")
    assert diff.shape == (QUERY.npix, QUERY.npix)
    assert d_tmpl.max() > d_epoch.max()  # template is the deeper stack
    cat = detect_sources(diff, d_epoch, d_tmpl, nsigma=5.0)
    recovered, spurious = match_detections(cat, QUERY, truths)
    assert recovered >= int(np.ceil(0.95 * len(truths)))
    assert spurious == 0
    assert (cat.snr >= 5.0).all()
    assert (cat.npix >= 1).all()
    assert (cat.flux > 0).all()          # transients were *added* flux


def test_static_sky_yields_zero_detections(static_engine):
    diff, d_epoch, d_tmpl = difference_image(static_engine, QUERY,
                                             reduce="clipped")
    cat = detect_sources(diff, d_epoch, d_tmpl, nsigma=5.0)
    assert len(cat) == 0
    # An empty catalog grades as nothing recovered, nothing spurious.
    assert match_detections(cat, QUERY, np.zeros((0, 2))) == (0, 0)


def test_max_sources_truncates_but_keeps_brightest(injected):
    eng, truths = injected
    diff, d_epoch, d_tmpl = difference_image(eng, QUERY, reduce="clipped")
    full = detect_sources(diff, d_epoch, d_tmpl, nsigma=5.0)
    trunc = detect_sources(diff, d_epoch, d_tmpl, nsigma=5.0, max_sources=3)
    assert len(trunc) == min(3, len(full))
    # top_k extraction: the truncated catalog is the highest-SNR prefix.
    np.testing.assert_array_equal(trunc.snr, np.sort(full.snr)[::-1][:3])


def test_difference_respects_chosen_run(injected):
    eng, truths = injected
    # Differencing against run 0 (pre-injection epoch) finds nothing: the
    # transients live only in the final run.
    diff, d_epoch, d_tmpl = difference_image(eng, QUERY, run=0,
                                             reduce="clipped")
    cat = detect_sources(diff, d_epoch, d_tmpl, nsigma=5.0)
    recovered, _ = match_detections(cat, QUERY, truths)
    assert recovered == 0


def test_mean_template_also_recovers(injected):
    # The drill's headline uses the clipped template; the plain mean must
    # work too (reduce= is orthogonal to the differencing contract).
    eng, truths = injected
    diff, d_epoch, d_tmpl = difference_image(eng, QUERY, reduce="mean",
                                             use_bricks=False)
    cat = detect_sources(diff, d_epoch, d_tmpl, nsigma=5.0)
    recovered, spurious = match_detections(cat, QUERY, truths)
    assert recovered >= int(np.ceil(0.95 * len(truths)))
    assert spurious == 0
