"""Parity tests for measured-PSF homogenization + the matched-pixel cache.

Two parity families (ISSUE 5 satellites):

* **Measured vs Gaussian fallback** — on a survey whose stamps are exact
  Gaussians, the measured-PSF path (Fourier-LS 2-D kernels) must reproduce
  the separable Gaussian path's coadds across all six methods, kernel
  on/off, batched, and streaming executors.  This pins the fallback as a
  true degenerate case of the measured machinery, end to end through the
  engine.

* **Cached vs uncached matched pixels** — the matched-pixel residency
  cache (DESIGN.md §7) moves the query-independent matching convolution
  from inside every dispatch to chunk-upload time.  It must be *bitwise*
  invisible to results and add zero per-query H2D traffic (upload-counter
  pinned), only per-query time.
"""

import numpy as np
import pytest

from repro.core import METHODS, CoaddEngine, CoaddQuery, SurveyConfig, make_survey

TARGET = 2.0
QUERY = CoaddQuery(
    band="r", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3), npix=32
)


@pytest.fixture(scope="module")
def gaussian_stamp_survey():
    """Stamps rendered as exact circular Gaussians (beta=None, no ellip
    jitter): the one case where measured and analytic kernels must agree.

    17 taps rather than the survey default 13: at 13 the sigma=2.0 target
    stamp truncates at 3 sigma (~1% of its mass), and the LS kernel
    faithfully matches to that *truncated* target — a real few-percent PSF
    difference, not a numerical one.  At 17 taps truncation is ~3e-4 and
    the two paths agree to the kernel-fidelity level the assert pins.
    """
    return make_survey(
        SurveyConfig(
            n_runs=2, n_fields=4, n_sources=60, height=16, width=16,
            moffat_beta=None, psf_ellip_jitter=0.0, psf_stamp_size=17,
        )
    )


@pytest.fixture(scope="module")
def moffat_survey():
    """The default measured-PSF survey (elliptical Moffat stamps)."""
    return make_survey(
        SurveyConfig(n_runs=2, n_fields=4, n_sources=60, height=16, width=16)
    )


@pytest.mark.parametrize("use_kernel", [False, True], ids=["xla", "pallas"])
@pytest.mark.parametrize("method", METHODS)
def test_measured_matches_gaussian_fallback(
    gaussian_stamp_survey, method, use_kernel
):
    sv = gaussian_stamp_survey
    eng_m = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET,
                        use_kernel=use_kernel)
    eng_g = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET,
                        use_kernel=use_kernel, measured_psf=False)
    r_m = eng_m.run(QUERY, method)
    r_g = eng_g.run(QUERY, method)
    assert r_m.depth.max() > 0
    # Depth is untouched by matching; coadds agree to kernel-fidelity level
    # (the 2-D LS kernel approximates the analytic Gaussian to ~1e-3 of the
    # per-pixel flux scale).
    np.testing.assert_array_equal(r_m.depth, r_g.depth)
    scale = max(float(np.abs(r_g.coadd).max()), 1.0)
    assert np.abs(r_m.coadd - r_g.coadd).max() / scale < 2e-3, method


def test_measured_matches_gaussian_fallback_batched(gaussian_stamp_survey):
    sv = gaussian_stamp_survey
    q2 = CoaddQuery(band="r", ra_bounds=(37.1, 37.6),
                    dec_bounds=(-0.4, 0.4), npix=32)
    eng_m = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET)
    eng_g = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET,
                        measured_psf=False)
    res_m = eng_m.run_batch([QUERY, q2], "sql_structured")
    res_g = eng_g.run_batch([QUERY, q2], "sql_structured")
    for rm, rg in zip(res_m, res_g):
        np.testing.assert_array_equal(rm.depth, rg.depth)
        scale = max(float(np.abs(rg.coadd).max()), 1.0)
        assert np.abs(rm.coadd - rg.coadd).max() / scale < 2e-3


def test_measured_matches_gaussian_fallback_streaming(gaussian_stamp_survey):
    sv = gaussian_stamp_survey
    eager = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET)
    exec_ds, _ = eager.exec_dataset("structured")
    budget = max(exec_ds.chunk_nbytes(0, exec_ds.n_packs) // 4, 1)
    eng_m = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET,
                        device_budget_bytes=budget)
    eng_g = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET,
                        device_budget_bytes=budget, measured_psf=False)
    r_m = eng_m.run(QUERY, "sql_structured")
    r_g = eng_g.run(QUERY, "sql_structured")
    assert r_m.stats.windows >= 2  # really streamed under the 4x budget
    np.testing.assert_array_equal(r_m.depth, r_g.depth)
    scale = max(float(np.abs(r_g.coadd).max()), 1.0)
    assert np.abs(r_m.coadd - r_g.coadd).max() / scale < 2e-3


# ----- matched-pixel cache: bitwise parity + traffic contract -----

@pytest.mark.parametrize("method", ["sql_structured", "raw_fits_prefiltered"])
def test_matched_cache_bitwise_parity(moffat_survey, method):
    """Caching the matching convolution at residency time must be bitwise
    invisible: same per-pack convolution program, just run once."""
    sv = moffat_survey
    eng_c = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET)
    eng_u = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET,
                        matched_pixel_cache=False)
    r_c = eng_c.run(QUERY, method)
    r_u = eng_u.run(QUERY, method)
    np.testing.assert_array_equal(r_c.coadd, r_u.coadd)
    np.testing.assert_array_equal(r_c.depth, r_u.depth)
    assert r_c.stats.matched_cache_builds == 1
    assert r_u.stats.matched_cache_builds == 0


def test_matched_cache_no_per_query_h2d(moffat_survey):
    """Repeat queries must hit the matched cache: zero pack uploads, zero
    rebuilds — the convolution happened once, at residency time."""
    sv = moffat_survey
    eng = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET)
    r1 = eng.run(QUERY, "sql_structured")
    assert r1.stats.matched_cache_builds == 1
    uploads0 = eng.pack_upload_count
    builds0 = eng.matched_builds
    for _ in range(3):
        r = eng.run(QUERY, "sql_structured")
        assert r.stats.matched_cache_hits == 1
        assert r.stats.matched_cache_builds == 0
    assert eng.pack_upload_count == uploads0
    assert eng.matched_builds == builds0
    # The derived entry is budget-counted but never upload-counted.
    assert eng.residency.derived_builds == 1
    assert eng.residency.uploads == 0


def test_matched_cache_streaming_reuses_chunks(moffat_survey):
    """Streaming matched mode: the chunk cache IS the matched cache — a
    repeat query re-reads matched chunks without re-uploading or
    re-convolving."""
    sv = moffat_survey
    eager = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET)
    exec_ds, _ = eager.exec_dataset("structured")
    # Budget comfortably above the working set: repeats must be pure hits.
    budget = exec_ds.chunk_nbytes(0, exec_ds.n_packs) * 2
    eng = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET,
                      device_budget_bytes=budget)
    r1 = eng.run(QUERY, "sql_structured")
    assert r1.stats.chunk_uploads == r1.stats.windows
    assert r1.stats.matched_cache_builds == r1.stats.windows
    uploads0, builds0 = eng.pack_upload_count, eng.matched_builds
    r2 = eng.run(QUERY, "sql_structured")
    assert eng.pack_upload_count == uploads0
    assert eng.matched_builds == builds0
    assert r2.stats.chunk_uploads == 0
    assert r2.stats.matched_cache_hits == r2.stats.windows
    np.testing.assert_array_equal(r1.coadd, r2.coadd)
    # Eager-vs-streaming parity of the matched result itself (window
    # accumulation reassociates float sums, hence the tolerance).
    r_e = eager.run(QUERY, "sql_structured")
    np.testing.assert_allclose(r2.coadd, r_e.coadd, atol=1e-3, rtol=1e-5)


def test_distributed_retune_resharded_bank(moffat_survey):
    """Regression: `run_distributed` after retuning match_psf_sigma must
    re-shard with the new target's bank, not serve the cached mesh dataset
    that baked in the old one (mesh cache is keyed per target)."""
    import jax

    sv = moffat_survey
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(data_axes=("data",), model_axis=None)
    eng = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.0)
    r_20 = eng.run_distributed([QUERY], mesh, **kw)[0]
    eng.match_psf_sigma = 2.6
    r_26 = eng.run_distributed([QUERY], mesh, **kw)[0]
    fresh = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.6)
    r_fresh = fresh.run_distributed([QUERY], mesh, **kw)[0]
    np.testing.assert_array_equal(r_26.coadd, r_fresh.coadd)
    assert np.abs(r_26.coadd - r_20.coadd).max() > 1e-4
    # One sharded copy per (layout, mesh): the 2.0 dataset was dropped.
    assert len(eng._mesh_cache) == 1


def test_distributed_streaming_retune_rebuilds_windows(moffat_survey):
    """Regression: streaming mesh *windows* key on the PSF state too — a
    retuned engine under a device budget must re-upload windows with the
    new bank, not hit the LRU on the old target's."""
    import jax

    sv = moffat_survey
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(data_axes=("data",), model_axis=None)
    probe = CoaddEngine(sv, pack_capacity=16)
    ds = probe.exec_dataset("structured")[0]
    budget = max(ds.chunk_nbytes(0, ds.n_packs) // 2, 1)
    eng = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.0,
                      device_budget_bytes=budget)
    r_20 = eng.run_distributed([QUERY], mesh, **kw)[0]
    eng.match_psf_sigma = 2.6
    r_26 = eng.run_distributed([QUERY], mesh, **kw)[0]
    fresh = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.6,
                        device_budget_bytes=budget)
    r_fresh = fresh.run_distributed([QUERY], mesh, **kw)[0]
    np.testing.assert_array_equal(r_26.coadd, r_fresh.coadd)
    assert np.abs(r_26.coadd - r_20.coadd).max() > 1e-4


def test_stale_plan_psf_target_rejected(moffat_survey):
    """A plan built under one PSF target must not execute under another —
    banks and matched caches are keyed per target."""
    sv = moffat_survey
    eng_a = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=TARGET)
    eng_b = CoaddEngine(sv, pack_capacity=16)
    plan = eng_a.plan(QUERY, "sql_structured")
    with pytest.raises(ValueError, match="psf_target"):
        eng_b.execute(plan)
    with pytest.raises(ValueError, match="psf_target"):
        eng_b.execute_batch([plan])
