"""Quickstart: build a synthetic Stripe-82 slice, run one coadd query.

PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey

survey = make_survey(SurveyConfig(n_runs=6, n_fields=8, n_sources=200,
                                  height=24, width=24))
print(f"survey: {len(survey)} CCD frames "
      f"({survey.config.n_runs} epochs x {survey.config.n_camcols} camcols "
      f"x {survey.config.n_bands} bands x {survey.config.n_fields} fields)")

engine = CoaddEngine(survey, pack_capacity=64)
query = CoaddQuery(band="r", ra_bounds=(37.5, 38.5), dec_bounds=(-0.5, 0.5), npix=128)

result = engine.run(query, "sql_structured")
s = result.stats
print(f"method={s.method} files={s.files_considered} "
      f"contributing={s.files_contributing} packs={s.packs_touched}")
print(f"locate {s.t_locate_s*1e3:.1f} ms | map+reduce {s.t_map_reduce_s*1e3:.1f} ms")
print(f"depth: min={result.depth.min():.0f} max={result.depth.max():.0f}")
np.save("/tmp/coadd.npy", result.normalized)
print("normalized coadd saved to /tmp/coadd.npy")
