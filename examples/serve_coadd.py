"""Coadd-as-a-service demo: 16 concurrent clients through `CoaddService`.

Runs the seeded serving drill (assertions on) — every response must be
bitwise-equal to a direct `engine.run`, with coalescing and zero shed.

PYTHONPATH=src python examples/serve_coadd.py
"""
from repro.launch.serve import main

main(["--clients", "16", "--pool", "8", "--drill"])
