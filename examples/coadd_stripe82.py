"""Full Table-1-style comparison + a multi-query distributed job.

PYTHONPATH=src python examples/coadd_stripe82.py
(The distributed demo uses however many local devices exist; on one CPU
device it degenerates gracefully to a 1x1 mesh.)

PYTHONPATH=src python examples/coadd_stripe82.py --detect
runs only the seeded difference-imaging drill (DESIGN.md §11): inject
transients into the newest epoch, difference it against the brick-served
robust template, detect at 5 sigma, and exit nonzero unless >= 95% of the
injections are recovered with zero false positives — on the injected AND
the static sky.
"""
import argparse
import sys

import jax
import numpy as np

from repro.core import CoaddEngine, CoaddQuery, METHODS, SurveyConfig, make_survey


def detect_drill(seed: int = 7, nsigma: float = 5.0) -> int:
    """Seeded transient-recovery drill; returns a process exit code."""
    from repro.core import (detect_sources, difference_image,
                            inject_transients, match_detections)

    cfg = SurveyConfig(n_runs=3, n_fields=5, n_sources=100,
                       height=20, width=20)
    query = CoaddQuery(band="r", ra_bounds=(37.3, 37.9),
                       dec_bounds=(-0.5, 0.3), npix=48)

    def run_sky(injected):
        sv = make_survey(cfg)
        truths = (inject_transients(sv, query, n=8, flux=400.0, seed=seed)
                  if injected else np.zeros((0, 2)))
        eng = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.0)
        diff, da, db = difference_image(eng, query, reduce="clipped")
        cat = detect_sources(diff, da, db, nsigma=nsigma)
        return truths, cat

    truths, cat = run_sky(injected=True)
    recovered, spurious = match_detections(cat, query, truths)
    _, static_cat = run_sky(injected=False)
    ok = (recovered >= int(np.ceil(0.95 * len(truths)))
          and spurious == 0 and len(static_cat) == 0)
    print(f"detect drill: seed={seed} nsigma={nsigma} "
          f"recovered={recovered}/{len(truths)} spurious={spurious} "
          f"static_sky_detections={len(static_cat)} "
          f"-> {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


_ap = argparse.ArgumentParser(description=__doc__)
_ap.add_argument("--detect", action="store_true",
                 help="run only the seeded difference-imaging drill")
_ap.add_argument("--seed", type=int, default=7)
_args = _ap.parse_args()
if _args.detect:
    sys.exit(detect_drill(seed=_args.seed))

survey = make_survey(SurveyConfig(n_runs=5, n_fields=8, n_sources=150,
                                  height=24, width=24))
engine = CoaddEngine(survey, pack_capacity=64)
large = CoaddQuery(band="r", ra_bounds=(37.4, 38.4), dec_bounds=(-0.5, 0.5), npix=128)
small = CoaddQuery(band="r", ra_bounds=(37.8, 38.05), dec_bounds=(-0.1, 0.15), npix=128)

print(f"{'method':32s} {'1deg considered':>16s} {'qdeg considered':>16s}")
for m in METHODS:
    r1 = engine.run(large, m)
    r2 = engine.run(small, m)
    print(f"{m:32s} {r1.stats.files_considered:16d} {r2.stats.files_considered:16d}")

# Batched multi-query single-host job (paper Fig. 5): one jitted dispatch.
batch = engine.run_batch([large, small], "sql_structured")
print(f"run_batch: {len(batch)} queries, "
      f"{sum(r.stats.dispatches for r in batch)} dispatch(es)")

# Robust stacking (DESIGN.md §11): the same query with outlier-rejecting
# estimators — the sigma-clipped mean re-scans once with fixed clip
# operands, the two-round median adds a binapprox histogram pass.
for red in ("clipped", "median"):
    rr = engine.run(large, "sql_structured", reduce=red)
    print(f"robust stack/{red}: passes={rr.stats.reduce_passes} "
          f"depth_max={rr.depth.max():.0f} "
          f"rejected={float((batch[0].depth - rr.depth).sum()):.1f} "
          f"coverage-units")

# PSF-homogenized coadd (DESIGN.md §7): convolve every exposure to a common
# target PSF before stacking, so the coadd has a well-defined point-spread
# function.  The target must sit at/above the *measured* widths (Moffat
# wings make those larger than the Gaussian-equivalent seeing) or the bank
# clamps — pick it from the stamps, like a production pipeline would.
from repro.core import psf  # noqa: E402

worst = 1.05 * float(
    max(psf.stamp_sigma(im.psf_stamp) for im in survey.images)
)
matched = CoaddEngine(survey, pack_capacity=64, match_psf_sigma=worst)
rm = matched.run(large, "sql_structured")
print(f"psf-homogenized to sigma={worst:.2f}px: depth_max={rm.depth.max():.0f} "
      f"(matched-pixel cache: {rm.stats.matched_cache_builds} build)")

# Fault-tolerant streaming (DESIGN.md §8): run the same query through a
# budgeted engine while a chaos schedule kills one chunk upload and poisons
# one pack's pixels with NaNs.  The WindowTracker retries the upload, scrubs
# the poison, and still produces the fault-free coadd — the per-query fault
# telemetry below is the audit trail.
from repro.core import ChaosInjector, FaultSchedule, PoisonSpec  # noqa: E402

ds = engine.exec_dataset("structured")[0]
budget = ds.chunk_nbytes(0, ds.n_packs) // 4  # 4x oversubscribed
# Aim the poison at a pack the query's gate actually opens, so the drill
# exercises the scrub-and-retry path rather than missing the query entirely.
gated = np.nonzero(engine._exec_gate(engine.plan(large, "sql_structured"))
                   .any(axis=1))[0]
drill = FaultSchedule(
    upload_fail_ordinals=(0,),
    poison=(PoisonSpec(pack=int(gated[0]), mode="nan", count=1),))
chaotic = CoaddEngine(survey, pack_capacity=64, device_budget_bytes=budget,
                      fault_injector=ChaosInjector(drill),
                      fault_backoff_s=1e-3)
clean = CoaddEngine(survey, pack_capacity=64, device_budget_bytes=budget)
rf = chaotic.run(large, "sql_structured")
rc = clean.run(large, "sql_structured")
s = rf.stats
print(f"chaos drill: bitwise_equal={bool(np.array_equal(rf.coadd, rc.coadd))} "
      f"retries={s.retries} speculative={s.speculative_windows} "
      f"quarantined={s.quarantined_packs} resumed={s.resumed_windows} "
      f"partial={s.partial}")

# Brick-tessellated materialized coadds (DESIGN.md §9): precompute the hot
# sky once, then serve repeat queries by mosaicking cached bricks.  The
# drill runs the same lattice window cold (misses materialize inline),
# warm (every tile a device-tier hit, zero archive scan), and spilled
# (device replicas dropped; tiles re-upload from the host copy) — all
# three bitwise-identical to the brick-free fresh scan.
bricky = CoaddEngine(survey, pack_capacity=64, brick_deg=0.5, brick_npix=64)
wq = bricky.brick_grid.window_query(1, 3, 1, 3, "r")
fresh = bricky.run_window(wq, "sql_structured")


def _brick_leg(name, r):
    s = r.stats
    print(f"brick drill/{name}: hit={s.bricks_hit} missed={s.bricks_missed} "
          f"spilled={s.bricks_spilled} "
          f"residual_packs_scanned={s.residual_packs_scanned} "
          f"bitwise_equal={bool(np.array_equal(r.coadd, fresh.coadd))}")


_brick_leg("cold", bricky.run(wq, "sql_structured", use_bricks=True))
_brick_leg("warm", bricky.run(wq, "sql_structured", use_bricks=True))
bricky.brick_store.drop_device()
_brick_leg("spilled", bricky.run(wq, "sql_structured", use_bricks=True))

# Batch-materialize the whole r-band lattice; the four drilled bricks are
# already in the store, so the journal skips them.
report = bricky.materialize_bricks(bands=("r",))
print(f"materialize_bricks: {len(report.tasks)} bricks, "
      f"completed={report.completed} skipped={report.skipped} "
      f"partial={report.partial_bricks}")

# Durable crash recovery (DESIGN.md §8.1): journal window partials to disk
# so a resume survives *process death*, not just an in-process kill.  The
# drill kills a journaled streaming query after its first window, then
# hands the same journal_dir to a brand-new engine — as a fresh process
# would — which replays the finished window from disk, re-dispatches only
# the missing ones, and reproduces the fault-free coadd bitwise.
import tempfile  # noqa: E402

from repro.core import FatalFault  # noqa: E402

jdir = tempfile.mkdtemp(prefix="coadd-journal-")
doomed = CoaddEngine(survey, pack_capacity=64, device_budget_bytes=budget,
                     journal_dir=jdir,
                     fault_injector=ChaosInjector(
                         FaultSchedule(kill_after_windows=1)))
try:
    doomed.run(large, "sql_structured")
except FatalFault as e:
    print(f"durable drill: query killed mid-stream ({e})")
revived = CoaddEngine(survey, pack_capacity=64, device_budget_bytes=budget,
                      journal_dir=jdir)
rr = revived.run(large, "sql_structured")
print(f"durable drill: resumed_windows={rr.stats.resumed_windows} "
      f"bitwise_equal={bool(np.array_equal(rr.coadd, rc.coadd))} "
      f"journals_left={revived.journal_store.jobs()}")

# Multi-query distributed job (paper Fig. 5: parallel reducers over queries).
n = len(jax.devices())
shape = (n, 1) if n > 1 else (1, 1)
mesh = jax.make_mesh(shape, ("data", "model"), devices=jax.devices()[: shape[0]*shape[1]])
queries = [
    CoaddQuery(band="g", ra_bounds=(37.4, 38.0), dec_bounds=(-0.4, 0.2), npix=64),
    CoaddQuery(band="r", ra_bounds=(37.6, 38.2), dec_bounds=(-0.2, 0.4), npix=64),
]
results = engine.run_distributed(queries, mesh, data_axes=("data",), model_axis=None)
for q, r in zip(queries, results):
    print(f"distributed band={q.band}: contributing={r.stats.files_contributing} "
          f"depth_max={r.depth.max():.0f}")
