"""Batched serving example: prefill + greedy decode on a reduced qwen2-1.5b.

PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

main(["--arch", "qwen2-1.5b", "--reduced", "--batch", "4",
      "--prompt-len", "32", "--gen", "16"])
