"""End-to-end training driver example: a reduced gemma-2b for 60 steps on a
synthetic packed-token corpus, with checkpointing on.

PYTHONPATH=src python examples/train_lm.py [--arch mamba2-130m]
"""
import sys

from repro.launch.train import main

arch = sys.argv[sys.argv.index("--arch") + 1] if "--arch" in sys.argv else "gemma-2b"
main([
    "--arch", arch, "--reduced",
    "--steps", "60", "--global-batch", "8", "--seq-len", "64",
    "--vocab", "512", "--run-dir", "/tmp/repro_train_example",
    "--ckpt-every", "20", "--log-every", "10",
])
