"""llama-3.2-vision-11b — decoder + cross-attn image layers every 5th layer;
vision frontend stubbed (precomputed patch embeddings) [hf:meta-llama]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    cross_attn_period=5,
    n_image_tokens=1600,
)
