"""Model / run configuration.

One frozen dataclass describes every assigned architecture; families select
block composition in `repro.models.model`:

  dense   — decoder-only transformer (GQA/MQA, SwiGLU/GeGLU)
  moe     — dense + mixture-of-experts MLP
  ssm     — attention-free Mamba-2 (SSD)
  hybrid  — Mamba-2 backbone + shared attention block (Zamba-2)
  encdec  — encoder-decoder (Whisper; conv frontend stubbed)
  vlm     — decoder + cross-attention layers to image tokens (Llama-3.2-V)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None          # default d_model // n_heads
    # --- layer flavor ---
    mlp_type: str = "swiglu"              # swiglu | geglu | gelu
    qkv_bias: bool = False
    pos_embed: str = "rope"               # rope | sinusoidal
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # tokens (Mixtral: 4096)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "onehot"              # onehot (GShard baseline) | scatter
    act_shard_axes: tuple = ()            # mesh data axes (set by launcher)
    pure_dp: bool = False                 # treat model axis as extra DP (small archs)
    param_mode: str = "fsdp"              # fsdp | zero1 (bf16 replicated compute params)
    seq_shard_activations: bool = False   # sequence-parallel residual stream
    # --- SSM (Mamba-2) ---
    ssm_state: int = 0
    ssm_chunk: int = 64
    conv_width: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssd_intra_dtype: str = "float32"      # intra-chunk math dtype (bf16 = perf)
    # --- hybrid (Zamba-2): one shared attn+MLP block every N ssm layers ---
    shared_attn_period: int = 6
    # --- encoder-decoder (Whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0                  # precomputed frame embeddings
    # --- VLM (Llama-3.2-Vision) ---
    cross_attn_period: int = 0            # every Nth layer gets cross-attn
    n_image_tokens: int = 0
    # --- numerics / training ---
    dtype: str = "bfloat16"               # activation/compute dtype
    param_dtype: str = "float32"          # master params
    remat: bool = True
    scan_layers: bool = True              # False: unroll (cost-model probes)
    force_dense_attn: bool = False        # probes: exact-flops dense attention
    tie_embeddings: bool = False
    embed_scale: bool = False          # gemma: scale embeddings by sqrt(d)
    logit_softcap: Optional[float] = None

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> can run long_500k."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and roofline)."""
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (self.n_heads * dh) * d
        gates = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        mlp = gates * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "dense":
            return self.n_layers * (attn + mlp) + emb
        if self.family == "moe":
            return self.n_layers * (attn + self.n_experts * mlp + d * self.n_experts) + emb
        if self.family == "ssm":
            ssm = self._ssm_block_params()
            return self.n_layers * ssm + emb
        if self.family == "hybrid":
            ssm = self._ssm_block_params()
            shared = attn + mlp
            return self.n_layers * ssm + shared + emb
        if self.family == "encdec":
            enc = self.n_encoder_layers * (attn + mlp)
            dec = self.n_layers * (2 * attn + mlp)  # self + cross
            return enc + dec + emb
        if self.family == "vlm":
            n_cross = self.n_layers // max(self.cross_attn_period, 1)
            return self.n_layers * (attn + mlp) + n_cross * attn + emb
        raise ValueError(self.family)

    def _ssm_block_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        in_proj = d * (2 * di + 2 * n + self.n_ssm_heads)
        conv = (di + 2 * n) * self.conv_width
        out_proj = di * d
        return in_proj + conv + out_proj

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dh = self.head_dim
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (self.n_heads * dh) * d
        gates = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        mlp = gates * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + self.top_k * mlp + d * self.n_experts) + emb


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}
