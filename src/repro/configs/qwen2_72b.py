"""qwen2-72b — GQA kv=8, QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    param_mode="zero1",    # §Perf B1: bf16 compute params, sharded masters
    seq_shard_activations=True,  # §Perf B3: TP all-reduce -> RS+AG
)
