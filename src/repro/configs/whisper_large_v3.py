"""whisper-large-v3 — enc-dec; conv frontend stubbed (precomputed frames)
[arXiv:2212.04356]. Decoder shapes follow the assignment, not the real
448-token ceiling (DESIGN.md §6)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_type="gelu",
    pos_embed="sinusoidal",
)
