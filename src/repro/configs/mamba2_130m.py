"""mamba2-130m — attention-free SSD [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_head=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    tie_embeddings=True,
    pure_dp=True,          # §Perf C1: 16-way model axis -> extra DP
    ssm_chunk=256,         # §Perf C3: state-carry traffic shrinks with L
)
