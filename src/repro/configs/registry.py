"""Architecture registry: --arch <id> -> ModelConfig."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_MODULES = {
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "gemma-7b": "repro.configs.gemma_7b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "gemma-2b": "repro.configs.gemma_2b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "llama-3.2-vision-11b": "repro.configs.llama_3p2_vision_11b",
    "mamba2-130m": "repro.configs.mamba2_130m",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced_config(arch: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses

    cfg = get_config(arch)
    return dataclasses.replace(
        cfg,
        n_layers=4 if cfg.family == "hybrid" else 2,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_seq else 0,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_head=16 if cfg.d_head else None,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_chunk=8,
        ssm_head_dim=16,
        sliding_window=8 if cfg.sliding_window else None,
        cross_attn_period=2 if cfg.cross_attn_period else 0,
        n_image_tokens=8 if cfg.n_image_tokens else 0,
        shared_attn_period=2,
        rope_theta=cfg.rope_theta,
        dtype="float32",
        remat=False,
    )
