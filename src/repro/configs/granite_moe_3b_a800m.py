"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8
[hf:ibm-granite]. The assignment line says 40e top-8 / d_ff=512 (its comment
mentions 32e); we implement the line literally — see DESIGN.md §6."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    moe_impl="shard_map",  # §Perf A4: ~10,000x on the dominant term
)
