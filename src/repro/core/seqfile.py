"""Sequence-file containers: packing many small images into few large arrays.

Paper §4.1.2–4.1.3: Hadoop performs poorly on many small files because job
init does serial per-file RPCs; *sequence files* concatenate small files into
few large indexed containers.  Two layouts are compared:

* **unstructured** — FITS files assigned to containers at random (Fig. 9 top).
  No container-level pruning is possible; every container must be read.
* **structured** — one container family per (band, camcol) CCD (Fig. 9
  bottom), mirroring the camera layout, so whole containers are pruned by the
  same glob logic that prefilters raw files.

TPU adaptation: a container is a dense ``(cap, H, W)`` pixel array plus
columnar metadata, i.e. exactly the layout a `shard_map` over the ``data``
axis wants.  "Few large files" becomes "few large device-resident arrays";
the per-file RPC cost becomes per-array dispatch cost, which `benchmarks/`
measures to reproduce Table 1's orderings.

An index (`SeqFileIndex`) maps image_id -> (pack, slot) — the sequence-file
index the paper's SQL method uses to build file splits (§4.1.4).
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import (
    Any,
    Callable,
    Dict,
    FrozenSet,
    Iterable,
    List,
    Optional,
    Sequence,
    Tuple,
)

import numpy as np

from repro.core.survey import Survey

META_COLS = (
    "image_id",
    "run",
    "camcol",
    "band_id",
    "field",
)
FLOAT_COLS = ("t_obs", "ra_min", "ra_max", "dec_min", "dec_max", "psf_sigma")


@dataclasses.dataclass
class DevicePackedDataset:
    """Device-resident form of a `PackedDataset` (DESIGN.md §3).

    The whole layout — every container — lives on device as one stacked
    pytree, uploaded **once** and cached by the engine, so repeated queries
    never re-transfer pixels.  Shapes mirror `PackedDataset`; arrays are
    `jax.Array`s.  Per-query state (the slot gate, the query vector, the
    output grid) stays tiny, which is what makes one-dispatch queries cheap.
    """

    pixels: "jax.Array"            # (P, cap, H, W) float32
    wcs: "jax.Array"               # (P, cap, 8) float32
    ints: Dict[str, "jax.Array"]   # (P, cap) int32 each; empty slots have
                                   #   image_id -1 (rejected by acceptance);
                                   #   slot validity itself stays host-side
                                   #   (PackedDataset.valid -> plan gates)
    floats: Dict[str, "jax.Array"] # (P, cap) float32 each

    @property
    def n_packs(self) -> int:
        return self.pixels.shape[0]

    @property
    def capacity(self) -> int:
        return self.pixels.shape[1]


@dataclasses.dataclass
class MeshResidentDataset:
    """A layout sharded *onto a device mesh* once and reused across jobs.

    The distributed sibling of `DevicePackedDataset`: containers are
    flattened to image-major ``(M, ...)`` arrays (padded so M divides the
    shard count), then `jax.device_put` with a `NamedSharding` over the data
    axes pins each shard to its device.  The engine caches one of these per
    (layout, mesh, shard_axes), so `run_distributed`'s per-job host traffic
    drops to slot gates + query vectors + output grids — the same residency
    win `DevicePackedDataset` gave the single-host path (DESIGN.md §4).
    """

    pixels: "jax.Array"            # (M, H, W) float32, sharded over axis 0
    wcs: "jax.Array"               # (M, 8)
    ints: Dict[str, "jax.Array"]   # (M,) int32 each; padded slots have
                                   #   image_id -1 (rejected by acceptance)
    floats: Dict[str, "jax.Array"] # (M,) float32 each
    psf_kernels: Optional["jax.Array"]  # (M, K) float32, or None
    n_flat: int                    # padded flat length M (static per cache key)


# Rebuild-cost classes for cost-aware eviction (DESIGN.md §9).  The number
# is a *class rank*, not a byte or second estimate: raw pixel chunks rebuild
# with one H2D copy, matched-pixel chunks additionally re-run the PSF
# convolution, and brick coadds rebuild only via a full streaming scan.
COST_RAW_CHUNK = 1.0
COST_MATCHED_CHUNK = 4.0
COST_BRICK = 16.0


@dataclasses.dataclass
class ResidentEntry:
    """One LRU-tracked resident payload (a pack chunk or a mesh window)."""

    key: Tuple
    payload: Any
    nbytes: int
    cost: float = COST_RAW_CHUNK  # rebuild-cost class (eviction priority)


class ResidencyManager:
    """Holds device-resident chunks under a byte budget with LRU eviction.

    The streaming half of the residency contract (DESIGN.md §6): instead of
    uploading a whole layout eagerly (`PackedDataset.to_device`), the engine
    asks this manager for *chunks* — contiguous pack-ranges keyed by
    ``(layout, start, stop)`` (mesh windows key themselves analogously with
    the mesh in the key).  A hit refreshes recency and costs nothing; a miss
    evicts least-recently-used entries until the new chunk fits, then calls
    the supplied builder (whose `jax.device_put` is *asynchronous* — the
    upload overlaps whatever the device is already scanning, which is what
    double-buffers the windowed executors).

    Eviction drops the LRU reference and lets the runtime free the buffers
    once in-flight consumers finish — never an explicit ``delete()``, so a
    chunk evicted while its scan is still enqueued stays valid for exactly
    as long as that scan needs it.  ``budget_bytes=None`` disables eviction
    (everything stays resident, the eager contract).

    Two classes of entry share the budget:

    * **uploaded** chunks (``h2d=True``, the default) — pixels crossing
      host->device; counted in ``uploads``/``bytes_uploaded``.
    * **derived** entries (``h2d=False``) — arrays *computed on device*
      from already-resident operands, e.g. the PSF matched-pixel cache.
      They occupy budget bytes like anything else but add zero H2D
      traffic, so they get their own ``derived_builds``/``derived_bytes``
      counters and never inflate the upload accounting tests pin.

    Eviction is **cost-aware** (DESIGN.md §9): every entry carries a
    rebuild-cost class (``cost``), and pressure evicts the least-recently-
    used entry of the *cheapest class present* — raw chunks (one H2D copy
    to rebuild) go before matched-pixel chunks (H2D + convolution), which
    go before bricks (a full streaming scan).  With uniform costs this
    degrades exactly to plain LRU, which the PR-4 eviction-order tests pin.

    ``peak_bytes`` reports *true* peak residency, not the advisory budget:
    eviction is drop-the-reference, so a chunk evicted while the most
    recently served entry's scan is still in flight stays alive device-side
    until that scan retires — the honest high-water mark is the resident
    bytes after an insert **plus** the in-flight entry the insert displaced
    (budget + one window's operands, matched-pixel cache included).
    """

    def __init__(self, budget_bytes: Optional[int] = None):
        if budget_bytes is not None and budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be positive, got {budget_bytes}")
        self.budget_bytes = budget_bytes
        self._lru: "OrderedDict[Tuple, ResidentEntry]" = OrderedDict()
        self.uploads = 0        # builder invocations (chunk misses, H2D)
        self.hits = 0           # entries served without a build
        self.evictions = 0      # entries dropped to make room
        self.bytes_uploaded = 0 # cumulative H2D bytes across all misses
        self.derived_builds = 0 # device-computed entries built (no H2D)
        self.derived_bytes = 0  # cumulative bytes of derived builds
        self.peak_bytes = 0     # true peak residency (see class docstring)
        self.failed_builds = 0  # builds that raised (no entry inserted)
        # Upload failure seam (DESIGN.md §8): called with the entry key on
        # every miss, right where a real transfer would be issued — chaos
        # drills hook `ChaosInjector.on_upload` here.  May raise.
        self.fault_hook: Optional[Callable[[Tuple], None]] = None
        # Eviction seam (DESIGN.md §9): called with (key, entry) after an
        # entry is dropped under pressure — the `BrickStore` counts its
        # device replicas spilling back to the host tier here.  Must not
        # raise; exceptions are deliberately not swallowed (a broken hook
        # is a bug, not weather).
        self.on_evict: Optional[Callable[[Tuple, ResidentEntry], None]] = None
        self._last_key: Optional[Tuple] = None  # most recently served entry
        # Persistent quarantine registry (DESIGN.md §8): packs whose host
        # data failed verification persistently, per execution layout, each
        # with the *reference* content digest recorded at detection time
        # (None when no pre-corruption digest existed).  Queries gate these
        # out until `reverify_quarantined` proves the host data repaired.
        self.quarantined: Dict[str, Dict[int, Optional[bytes]]] = {}
        self.quarantine_released = 0  # packs restored by re-verification

    # ----- persistent quarantine (DESIGN.md §8) -----
    def quarantine_packs(
        self,
        layout: str,
        packs: Iterable[int],
        digests: Optional[Sequence[Optional[bytes]]] = None,
    ) -> None:
        """Register persistently poisoned packs for ``layout``.

        ``digests`` is the per-pack reference digest list (the host
        seqfile's `pack_digests` cache) when one predates the corruption;
        packs without a reference re-verify on the NaN/Inf scan alone.
        """
        reg = self.quarantined.setdefault(layout, {})
        for p in packs:
            p = int(p)
            ref = None
            if digests is not None and p < len(digests):
                ref = digests[p]
            reg.setdefault(p, ref)

    def quarantined_packs(self, layout: str) -> FrozenSet[int]:
        return frozenset(self.quarantined.get(layout, ()))

    def reverify_quarantined(self, layout: str, exec_ds) -> List[int]:
        """Re-hash quarantined packs against the host seqfile; release matches.

        A pack is released when its *current* host pixels are finite and —
        when a reference digest was recorded at quarantine time — hash back
        to that reference: the host data was repaired (or was never bad,
        only its transfers were).  Released packs leave the registry, their
        sanitized chunk-cache entries drop (so the next query rebuilds full
        coverage), and ``quarantine_released`` counts them.
        """
        reg = self.quarantined.get(layout)
        if not reg:
            return []
        released: List[int] = []
        for p, ref in sorted(reg.items()):
            row = np.ascontiguousarray(exec_ds.pixels[p])
            if not np.isfinite(row).all():
                continue  # still poisoned
            if ref is not None and hashlib.sha256(row.tobytes()).digest() != ref:
                continue  # finite but still not the ingested bytes
            released.append(p)
        for p in released:
            del reg[p]
        if not reg:
            del self.quarantined[layout]
        if released:
            # Sanitized chunks (key carries the "quarantine" drop tuple)
            # are stale now; drop them so coverage rebuilds immediately.
            self.drop_matching(
                lambda k: isinstance(k, tuple) and "quarantine" in k
                and k and k[0] == layout
            )
            self.quarantine_released += len(released)
        return released

    @property
    def bytes_resident(self) -> int:
        return sum(e.nbytes for e in self._lru.values())

    @property
    def n_resident(self) -> int:
        return len(self._lru)

    def acquire(
        self,
        key: Tuple,
        nbytes: int,
        build: Callable[[], Any],
        h2d: bool = True,
        transient_bytes: int = 0,
        cost: float = COST_RAW_CHUNK,
    ) -> Any:
        """Return the resident payload for ``key``, building on miss.

        ``h2d=False`` marks a *derived* entry (computed on device from
        resident operands): budget-counted, but not upload-counted.
        ``transient_bytes`` declares device bytes the *build itself* holds
        alive beyond the entry (e.g. the raw pixel chunk a matched-pixel
        build convolves from, dropped once the convolution retires) — they
        join the peak candidate so the high-water mark stays honest.
        ``cost`` is the entry's rebuild-cost class (see class docstring):
        eviction pressure takes the LRU entry of the cheapest class first.
        """
        entry = self._lru.get(key)
        if entry is not None:
            self._lru.move_to_end(key)
            self.hits += 1
            self._last_key = key
            return entry.payload
        in_flight = 0
        if self.budget_bytes is not None:
            # Evict until the newcomer fits: cheapest rebuild-cost class
            # first, LRU within the class (OrderedDict iteration order IS
            # recency, oldest first, so the first minimum wins ties).  A
            # chunk larger than the whole budget still loads (the scan
            # needs it); the budget is then transiently exceeded by that
            # one chunk, never by two.
            while self._lru and self.bytes_resident + nbytes > self.budget_bytes:
                victim = min(
                    self._lru, key=lambda k: self._lru[k].cost
                )
                evicted = self._lru.pop(victim)
                self.evictions += 1
                if self.on_evict is not None:
                    self.on_evict(victim, evicted)
                if victim == self._last_key:
                    # The entry a consumer may still be scanning: its
                    # buffers outlive the eviction until that scan retires.
                    in_flight = evicted.nbytes
        try:
            if self.fault_hook is not None:
                self.fault_hook(key)
            payload = build()
        except BaseException:
            # Failed-build contract: no entry is inserted and no upload is
            # counted, so a retry re-acquires cleanly.  Evictions already
            # performed stand — the newcomer's room was made, the newcomer
            # never arrived — which keeps the LRU consistent (budget is an
            # upper bound, never violated by a failure).
            self.failed_builds += 1
            raise
        self._lru[key] = ResidentEntry(key, payload, nbytes, cost)
        if h2d:
            self.uploads += 1
            self.bytes_uploaded += nbytes
        else:
            self.derived_builds += 1
            self.derived_bytes += nbytes
        self.peak_bytes = max(
            self.peak_bytes,
            self.bytes_resident + in_flight + max(transient_bytes, 0),
        )
        self._last_key = key
        return payload

    def resident(self, key: Tuple) -> bool:
        """Whether ``key`` is device-resident right now (no recency touch)."""
        return key in self._lru

    def drop_matching(self, pred: Callable[[Tuple], bool]) -> int:
        """Drop entries whose key satisfies ``pred`` (a deliberate release
        — e.g. a retuned engine shedding the old PSF target's matched
        pixels — not budget pressure, so ``evictions`` is untouched;
        reference-drop semantics as ever)."""
        stale = [k for k in self._lru if pred(k)]
        for k in stale:
            del self._lru[k]
            if k == self._last_key:
                self._last_key = None
        return len(stale)

    def clear(self) -> None:
        """Drop every resident entry (a reset, not budget pressure — the
        ``evictions`` counter tracks only LRU evictions forced by misses)."""
        self._lru.clear()
        self._last_key = None


@dataclasses.dataclass
class BrickMeta:
    """Provenance a materialized brick carries into mosaicked results."""

    partial: bool = False                    # quarantine removed coverage
    uncovered_packs: Tuple[int, ...] = ()    # exec-layout packs missing
    files_considered: int = 0
    files_contributing: int = 0


class BrickStore:
    """The materialized-coadd tier of the residency hierarchy (DESIGN.md §9).

    Two tiers per (brick, band, psf_state) key:

    * a **host tier** (always populated at `put` time — the D2H already
      happened when the brick's `CoaddResult` synced, so keeping the copy
      is free) holding the coadd + weight (depth) maps and `BrickMeta`.
      This is also the materialization journal: `CoaddEngine.
      materialize_bricks` skips any brick already present, which is what
      makes a killed materialization resume instead of restart.
    * a **device tier**: entries in the shared `ResidencyManager` under the
      LRU budget, at `COST_BRICK` (most expensive rebuild class).  Eviction
      under pressure drops only the device replica — the host copy stands,
      so a later query re-uploads (one H2D copy) instead of re-scanning the
      archive.  ``spilled`` counts those pressure drops via the manager's
      eviction seam; ``spill_loads`` counts serves that had to re-upload.

    Staleness is carried by the key, never checked here: the engine keys
    bricks on its ``_psf_state()``, so a retuned engine misses and
    re-materializes rather than mosaicking stale tiles.

    With a ``spill`` backend (`durable.BrickSpill`, wired by
    ``CoaddEngine(journal_dir=...)``) the host tier is *persistent*: every
    `put` writes an atomically renamed, self-checksummed file, and lookups
    that miss the in-memory host dict reload (and digest-verify) from disk
    — so materialized bricks survive process death, and `materialize_bricks`
    in a fresh process skips them.  A reload that fails verification counts
    a plain miss (the file is dropped) and the brick rematerializes.
    """

    def __init__(self, residency: ResidencyManager, spill=None):
        self.residency = residency
        self.spill = spill
        self._host: Dict[Tuple, Tuple[np.ndarray, np.ndarray, BrickMeta]] = {}
        self.hits = 0         # serves straight from the device tier
        self.spill_loads = 0  # serves that re-uploaded the host copy
        self.misses = 0       # lookups with no materialized brick at all
        self.spilled = 0      # device replicas dropped under LRU pressure
        self.disk_loads = 0   # host-tier reloads from the persistent spill
        prev = residency.on_evict

        def _count_spill(key: Tuple, entry: ResidentEntry) -> None:
            if isinstance(key, tuple) and key and key[0] == "brick":
                self.spilled += 1
            if prev is not None:
                prev(key, entry)

        residency.on_evict = _count_spill

    def __len__(self) -> int:
        return len(self._host)

    def contains(self, key: Tuple) -> bool:
        """Whether a verified brick exists (in memory or reloadable).

        The materialization journal check: a disk candidate is loaded and
        digest-verified *here*, so a corrupted spill file never reports a
        brick as done — it rematerializes instead.
        """
        return key in self._host or self._load_spill(key)

    def _load_spill(self, key: Tuple) -> bool:
        """Reload ``key`` from the persistent spill into the host tier."""
        if self.spill is None or key in self._host:
            return key in self._host
        got = self.spill.load(key)  # digest-verified; corrupt -> None
        if got is None:
            return False
        coadd, depth, meta = got
        self._host[key] = (
            coadd,
            depth,
            BrickMeta(
                partial=bool(meta.get("partial", False)),
                uncovered_packs=tuple(meta.get("uncovered_packs", ())),
                files_considered=int(meta.get("files_considered", 0)),
                files_contributing=int(meta.get("files_contributing", 0)),
            ),
        )
        self.disk_loads += 1
        return True

    def keys(self):
        return self._host.keys()

    def meta(self, key: Tuple) -> BrickMeta:
        self._load_spill(key)
        return self._host[key][2]

    def host_arrays(self, key: Tuple) -> Tuple[np.ndarray, np.ndarray]:
        """The host-tier (coadd, depth) copies — test/debug access."""
        self._load_spill(key)
        coadd, depth, _ = self._host[key]
        return coadd, depth

    def _nbytes(self, key: Tuple) -> int:
        coadd, depth, _ = self._host[key]
        return int(coadd.nbytes) + int(depth.nbytes)

    def _acquire(self, key: Tuple):
        import jax  # deferred: the host tier itself is jax-free

        coadd, depth, _ = self._host[key]
        return self.residency.acquire(
            key,
            self._nbytes(key),
            lambda: (jax.device_put(coadd), jax.device_put(depth)),
            h2d=True,
            cost=COST_BRICK,
        )

    def put(
        self,
        key: Tuple,
        coadd: np.ndarray,
        depth: np.ndarray,
        meta: Optional[BrickMeta] = None,
    ):
        """Store a finished brick (host write-through + device insert).

        Returns the device-tier (coadd, depth) payload so the caller can
        mosaic immediately without a store lookup (which would miscount a
        fresh insert as a cache hit).
        """
        m = meta or BrickMeta()
        self._host[key] = (
            np.asarray(coadd, np.float32),
            np.asarray(depth, np.float32),
            m,
        )
        if self.spill is not None:
            # Durable write-through (DESIGN.md §8): the brick survives
            # process death; a crashed materialization resumes past it.
            self.spill.save(
                key,
                self._host[key][0],
                self._host[key][1],
                {
                    "partial": bool(m.partial),
                    "uncovered_packs": [int(p) for p in m.uncovered_packs],
                    "files_considered": int(m.files_considered),
                    "files_contributing": int(m.files_contributing),
                },
            )
        return self._acquire(key)

    def fetch(self, key: Tuple):
        """``(coadd_dev, depth_dev, meta, tier)`` or None when absent.

        ``tier`` is ``"device"`` (already resident) or ``"host"`` (the
        spill path: the device replica was evicted; serving re-uploads)."""
        if key not in self._host and not self._load_spill(key):
            self.misses += 1
            return None
        was_resident = self.residency.resident(key)
        payload = self._acquire(key)
        if was_resident:
            self.hits += 1
        else:
            self.spill_loads += 1
        coadd, depth = payload
        return coadd, depth, self._host[key][2], (
            "device" if was_resident else "host"
        )

    def drop_device(self) -> int:
        """Drop every device replica (host tier stands) — the deliberate
        spill used by tests/drills; LRU pressure does this organically."""
        return self.residency.drop_matching(
            lambda k: isinstance(k, tuple) and bool(k) and k[0] == "brick"
        )

    def clear(self) -> None:
        """Forget every materialized brick — all tiers, disk included."""
        self._host.clear()
        self.drop_device()
        if self.spill is not None:
            self.spill.clear()


@dataclasses.dataclass
class SlotRemap:
    """Slot-index remap from a layout's (P, cap) grid onto a reblocked one.

    Produced by `PackedDataset.reblock`; `apply` rewrites a plan gate built
    against the original layout into the reblocked coordinates.  Invalid
    source slots map to -1 and never appear in a gate (plans AND with
    ``valid``), so the scatter below only ever writes real destinations.
    """

    rb_pack: np.ndarray            # (P, cap) int32 — destination pack or -1
    rb_slot: np.ndarray            # (P, cap) int32 — destination slot or -1
    shape: Tuple[int, int]         # reblocked (n_packs, capacity)

    def apply(self, gate: np.ndarray) -> np.ndarray:
        """(P, cap) bool gate -> equivalent gate over the reblocked layout."""
        out = np.zeros(self.shape, bool)
        out[self.rb_pack[gate], self.rb_slot[gate]] = True
        return out


@dataclasses.dataclass
class PackedDataset:
    """A set of sequence-file containers.

    pixels:  (P, cap, H, W) float32 — container pixel payloads.
    wcs:     (P, cap, 8)    float32 — per-image WCS vectors.
    valid:   (P, cap)       bool    — slot occupancy (containers may be ragged).
    int metadata columns: (P, cap) int32 each; float columns likewise.
    pack_band / pack_camcol: (P,) int32 — container key for structured packs
      (-1 where mixed, i.e. unstructured).
    """

    layout: str  # "per_file" | "unstructured" | "structured"
    pixels: np.ndarray
    wcs: np.ndarray
    valid: np.ndarray
    ints: Dict[str, np.ndarray]
    floats: Dict[str, np.ndarray]
    pack_band: np.ndarray
    pack_camcol: np.ndarray
    index: Dict[int, Tuple[int, int]]  # image_id -> (pack, slot)
    # Measured-PSF calibration column (paper footnote 2): a fixed-size
    # (P, cap, S, S) stamp per slot, or None when the survey carries none.
    # Host-only — the engine turns stamps into a device kernel bank
    # (`psf.homogenization_bank`) at plan time; raw stamps never upload.
    psf_stamps: Optional[np.ndarray] = None

    @property
    def n_packs(self) -> int:
        return self.pixels.shape[0]

    @property
    def capacity(self) -> int:
        return self.pixels.shape[1]

    @property
    def n_images(self) -> int:
        return int(self.valid.sum())

    def image_hw(self) -> Tuple[int, int]:
        return self.pixels.shape[2], self.pixels.shape[3]

    def to_device(self) -> DevicePackedDataset:
        """Upload the whole layout to device, once (DESIGN.md §3).

        The eager residency contract: with no device budget configured this
        is the only place pack pixels cross host->device; everything
        downstream indexes/masks the resident arrays on device.  Streaming
        residency uploads `to_device_chunk` windows instead (§6).
        """
        import jax.numpy as jnp  # deferred: packing itself is jax-free

        return DevicePackedDataset(
            pixels=jnp.asarray(self.pixels),
            wcs=jnp.asarray(self.wcs),
            ints={k: jnp.asarray(v) for k, v in self.ints.items()},
            floats={k: jnp.asarray(v) for k, v in self.floats.items()},
        )

    def to_device_chunk(
        self, start: int, stop: int, pixels: Optional[np.ndarray] = None
    ) -> DevicePackedDataset:
        """Upload the pack-range [start, stop) as its own resident chunk.

        The `jax.device_put` calls are asynchronous: the host returns as
        soon as the transfers are enqueued, so a chunk uploaded while the
        device scans the previous one overlaps H2D with compute — the
        double-buffering the streaming executor relies on (DESIGN.md §6).

        ``pixels`` overrides the staged pixel slice — the fault-tolerant
        build path (DESIGN.md §8) stages, verifies, and possibly sanitizes
        a host copy (quarantined pack rows zeroed) before the upload.
        """
        import jax  # deferred: packing itself is jax-free

        sl = slice(start, stop)
        put = jax.device_put
        return DevicePackedDataset(
            pixels=put(self.pixels[sl] if pixels is None else pixels),
            wcs=put(self.wcs[sl]),
            ints={k: put(v[sl]) for k, v in self.ints.items()},
            floats={k: put(v[sl]) for k, v in self.floats.items()},
        )

    # ----- chunk verification (DESIGN.md §8) -----
    def pack_digests(self) -> List[bytes]:
        """Per-pack content digests of the *host* pixels (the ground truth).

        Built lazily on first use and cached: the host seqfile is immutable
        once packed, so these digests are what a staged chunk must reproduce
        for `verify_chunk`'s corruption check.
        """
        cache = getattr(self, "_pack_digest_cache", None)
        if cache is None:
            cache = [
                hashlib.sha256(
                    np.ascontiguousarray(self.pixels[p]).tobytes()
                ).digest()
                for p in range(self.n_packs)
            ]
            self._pack_digest_cache = cache
        return cache

    def verify_chunk(
        self,
        start: int,
        stop: int,
        pixels: np.ndarray,
        skip: FrozenSet[int] = frozenset(),
        check_digests: bool = False,
    ) -> List[int]:
        """Global pack indices in [start, stop) whose staged pixels are bad.

        Poison detection for the fault-tolerant build path: a pack fails on
        non-finite values (NaN/Inf — the cheap scan, always on) or, with
        ``check_digests``, on a content digest mismatch against the host
        seqfile (catches finite corruption too, at sha256 cost per build).
        ``skip`` holds already-quarantined packs, whose rows are about to be
        sanitized and must not re-trip detection.
        """
        bad: List[int] = []
        for local in range(stop - start):
            g = start + local
            if g in skip:
                continue
            row = pixels[local]
            if not np.isfinite(row).all():
                bad.append(g)
                continue
            if check_digests:
                d = hashlib.sha256(np.ascontiguousarray(row).tobytes()).digest()
                if d != self.pack_digests()[g]:
                    bad.append(g)
        return bad

    def pack_nbytes(self) -> int:
        """Host bytes of ONE pack (pixels + wcs + metadata columns)."""
        per_pack = (
            self.pixels[0].nbytes
            + self.wcs[0].nbytes
            + sum(v[0].nbytes for v in self.ints.values())
            + sum(v[0].nbytes for v in self.floats.values())
        )
        return int(per_pack)

    def chunk_nbytes(self, start: int, stop: int) -> int:
        """Device bytes a resident [start, stop) chunk will occupy."""
        return self.pack_nbytes() * max(stop - start, 0)

    def slot_mask(self, image_ids) -> np.ndarray:
        """(P, cap) bool gate selecting exactly `image_ids` (the SQL splits).

        Host-side and metadata-only — the device never sees the id list,
        just this static-shape mask.
        """
        mask = np.zeros((self.n_packs, self.capacity), bool)
        for i in image_ids:
            p, s = self.index[int(i)]
            mask[p, s] = True
        return mask

    def flat_slot_mask(self, image_ids, pad_to: Optional[int] = None) -> np.ndarray:
        """(M,) bool gate over the flattened (pack*cap) slot axis.

        The mesh-resident analogue of `slot_mask`: selection stays host-side
        and metadata-only, and this mask (not pixels) is the only per-job
        payload `run_distributed` ships to the mesh.
        """
        m = self.n_packs * self.capacity
        mask = np.zeros((pad_to or m,), bool)
        for i in image_ids:
            p, s = self.index[int(i)]
            mask[p * self.capacity + s] = True
        return mask

    def flat_len(self, n_shards: int) -> int:
        """Padded image-major flat length M for an ``n_shards``-way split."""
        m = self.n_packs * self.capacity
        return int(np.ceil(m / n_shards) * n_shards)

    def to_mesh(
        self,
        mesh,
        shard_axes: Tuple[str, ...],
        psf_kernels: Optional[np.ndarray] = None,
    ) -> MeshResidentDataset:
        """Shard this layout onto `mesh` once (DESIGN.md §4).

        Flattens (P, cap) -> (M,) image-major, pads M up to the shard count
        with invalid slots (image_id -1, valid False — the same phantom-proof
        padding `_accept_from_meta` already rejects), and `device_put`s every
        array with a `NamedSharding` over ``shard_axes``.  With no device
        budget this is the only place distributed pixels cross host->mesh;
        the engine caches the result per (layout, mesh, shard_axes).
        """
        from repro.distributed.sharding import shard_count

        pad_to = self.flat_len(shard_count(mesh, shard_axes))
        return self.to_mesh_window(mesh, shard_axes, 0, pad_to, psf_kernels)

    def to_mesh_window(
        self,
        mesh,
        shard_axes: Tuple[str, ...],
        start: int,
        stop: int,
        psf_kernels: Optional[np.ndarray] = None,
    ) -> MeshResidentDataset:
        """Shard the flat-axis window [start, stop) onto `mesh` (DESIGN.md §6).

        The streaming sibling of `to_mesh`: the window bounds index the
        *padded* image-major flat axis (``flat_len``) and must be multiples
        of the shard count so every device receives an equal slab of the
        window.  Uploads are `jax.device_put` — asynchronous, so a window
        shipped while the mesh maps the previous one overlaps H2D with
        compute exactly like the single-host chunk path.
        """
        import jax  # deferred: packing itself is jax-free

        from repro.distributed.sharding import image_axis_sharding, shard_count

        m = self.n_packs * self.capacity
        n_shards = shard_count(mesh, shard_axes)
        if (stop - start) % n_shards or start % n_shards:
            raise ValueError(
                f"window [{start}, {stop}) must align to {n_shards} shards"
            )

        def flat(a: np.ndarray, fill) -> np.ndarray:
            a = a.reshape((m,) + a.shape[2:])
            if stop > m:
                a = np.concatenate(
                    [a[start:m],
                     np.full((stop - max(start, m),) + a.shape[1:], fill, a.dtype)]
                )
            else:
                a = a[start:stop]
            return a

        sharding = image_axis_sharding(mesh, shard_axes)
        put = lambda a: jax.device_put(a, sharding)  # noqa: E731
        return MeshResidentDataset(
            pixels=put(flat(self.pixels, 0)),
            wcs=put(flat(self.wcs, 0)),
            ints={k: put(flat(v, -1)) for k, v in self.ints.items()},
            floats={k: put(flat(v, 0)) for k, v in self.floats.items()},
            psf_kernels=None if psf_kernels is None
            else put(flat(psf_kernels, 0)),
            n_flat=stop - start,
        )

    def reblock(self, capacity: int) -> Tuple["PackedDataset", "SlotRemap"]:
        """Re-pack into dense super-packs of ``capacity`` slots (DESIGN.md §5).

        The per-file layout is degenerate for the scan executor — (P=N,
        cap=1) pays one scan step per *image*, so its per-image cost is pure
        scan overhead relative to cap=64 containers.  Reblocking is a
        residency-time remedy: occupied slots are re-packed, in (band,
        camcol) order, into ceil(N/capacity) dense super-packs, and the
        returned `SlotRemap` rewrites any (P, cap) plan gate into the
        reblocked coordinates — so planning semantics (which *files* a
        method locates) are untouched while execution scans ~N/capacity
        steps.  The (band, camcol) ordering mirrors `pack_structured`'s
        container key: glob-prefiltered gates select contiguous slot runs,
        which keeps them sparse in *pack* space too (few super-packs
        opened), exactly what the sparse gather path wants.
        """
        pp, ss = np.nonzero(self.valid)
        order = np.lexsort(
            (self.ints["camcol"][pp, ss], self.ints["band_id"][pp, ss])
        )
        pp, ss = pp[order], ss[order]
        n = len(pp)
        if n == 0:
            raise ValueError("cannot reblock an empty dataset")
        n_packs = int(np.ceil(n / capacity))
        h, w = self.image_hw()
        dest_p = np.arange(n) // capacity
        dest_s = np.arange(n) % capacity
        pixels = np.zeros((n_packs, capacity, h, w), np.float32)
        wcs = np.zeros((n_packs, capacity, 8), np.float32)
        valid = np.zeros((n_packs, capacity), bool)
        ints = {k: np.full((n_packs, capacity), -1, np.int32) for k in self.ints}
        floats = {k: np.zeros((n_packs, capacity), np.float32) for k in self.floats}
        pixels[dest_p, dest_s] = self.pixels[pp, ss]
        wcs[dest_p, dest_s] = self.wcs[pp, ss]
        valid[dest_p, dest_s] = True
        psf_stamps = None
        if self.psf_stamps is not None:
            psf_stamps = np.zeros(
                (n_packs, capacity) + self.psf_stamps.shape[2:], np.float32
            )
            psf_stamps[dest_p, dest_s] = self.psf_stamps[pp, ss]
        for k in self.ints:
            ints[k][dest_p, dest_s] = self.ints[k][pp, ss]
        for k in self.floats:
            floats[k][dest_p, dest_s] = self.floats[k][pp, ss]
        index = {
            int(ints["image_id"][p, s]): (int(p), int(s))
            for p, s in zip(dest_p, dest_s)
        }
        # Container keys: uniform within a super-pack or -1 (mixed).
        def pack_key(col):
            vals = np.where(valid, col, -1)
            first = vals[np.arange(n_packs), 0]
            uniform = np.all((vals == first[:, None]) | ~valid, axis=1)
            return np.where(uniform, first, -1).astype(np.int32)

        ds = PackedDataset(
            layout=self.layout,
            pixels=pixels,
            wcs=wcs,
            valid=valid,
            ints=ints,
            floats=floats,
            pack_band=pack_key(ints["band_id"]),
            pack_camcol=pack_key(ints["camcol"]),
            index=index,
            psf_stamps=psf_stamps,
        )
        rb_pack = np.full(self.valid.shape, -1, np.int32)
        rb_slot = np.full(self.valid.shape, -1, np.int32)
        rb_pack[pp, ss] = dest_p
        rb_slot[pp, ss] = dest_s
        return ds, SlotRemap(rb_pack, rb_slot, (n_packs, capacity))

    def gather(self, image_ids: np.ndarray, pad_to: Optional[int] = None):
        """Gather a dense mapper-input batch for an exact id list.

        Returns (pixels (N,H,W), wcs (N,8), meta dict, valid (N,)) with
        optional padding so callers can keep static shapes. Also returns the
        number of distinct packs touched — the paper's "mapper object"
        locality statistic (§4.1.4).
        """
        locs = [self.index[int(i)] for i in image_ids]
        packs = np.array([p for p, _ in locs], np.int32)
        slots = np.array([s for _, s in locs], np.int32)
        n = len(locs)
        pad = (pad_to or n) - n
        if pad < 0:
            raise ValueError(f"pad_to={pad_to} < n={n}")
        px = self.pixels[packs, slots]
        wv = self.wcs[packs, slots]
        ints = {k: v[packs, slots] for k, v in self.ints.items()}
        floats = {k: v[packs, slots] for k, v in self.floats.items()}
        valid = np.ones((n,), bool)
        if pad:
            px = np.concatenate([px, np.zeros((pad,) + px.shape[1:], px.dtype)])
            wv = np.concatenate([wv, np.tile(wv[-1:], (pad, 1))])
            ints = {k: np.concatenate([v, np.full((pad,), -1, v.dtype)]) for k, v in ints.items()}
            floats = {k: np.concatenate([v, np.zeros((pad,), v.dtype)]) for k, v in floats.items()}
            valid = np.concatenate([valid, np.zeros((pad,), bool)])
        n_packs_touched = len(np.unique(packs))
        return px, wv, ints, floats, valid, n_packs_touched


def _emit(
    layout: str,
    groups: List[np.ndarray],
    survey: Survey,
    group_band: List[int],
    group_camcol: List[int],
) -> PackedDataset:
    tab = survey.meta_table()
    h, w = survey.config.height, survey.config.width
    cap = max(len(g) for g in groups)
    P = len(groups)
    pixels = np.zeros((P, cap, h, w), np.float32)
    wcs = np.zeros((P, cap, 8), np.float32)
    valid = np.zeros((P, cap), bool)
    ints = {k: np.full((P, cap), -1, np.int32) for k in META_COLS}
    floats = {k: np.zeros((P, cap), np.float32) for k in FLOAT_COLS}
    index: Dict[int, Tuple[int, int]] = {}
    stamp0 = survey.images[0].psf_stamp if len(survey.images) else None
    psf_stamps = (
        None if stamp0 is None
        else np.zeros((P, cap) + stamp0.shape, np.float32)
    )
    for p, ids in enumerate(groups):
        for s, img_id in enumerate(ids):
            im = survey.images[int(img_id)]
            pixels[p, s] = im.pixels
            wcs[p, s] = im.wcs.to_vector()
            valid[p, s] = True
            if psf_stamps is not None:
                psf_stamps[p, s] = im.psf_stamp
            for k in META_COLS:
                ints[k][p, s] = tab[k][img_id]
            for k in FLOAT_COLS:
                floats[k][p, s] = tab[k][img_id]
            index[int(img_id)] = (p, s)
    return PackedDataset(
        layout=layout,
        pixels=pixels,
        wcs=wcs,
        valid=valid,
        ints=ints,
        floats=floats,
        pack_band=np.array(group_band, np.int32),
        pack_camcol=np.array(group_camcol, np.int32),
        index=index,
        psf_stamps=psf_stamps,
    )


def pack_per_file(survey: Survey) -> PackedDataset:
    """Each image is its own 'file' (the paper's raw-FITS baseline)."""
    ids = np.arange(len(survey))
    groups = [np.array([i]) for i in ids]
    tab = survey.meta_table()
    return _emit(
        "per_file",
        groups,
        survey,
        [int(tab["band_id"][i]) for i in ids],
        [int(tab["camcol"][i]) for i in ids],
    )


def pack_unstructured(survey: Survey, pack_capacity: int = 64, seed: int = 0) -> PackedDataset:
    """Random assignment of images to containers (Fig. 9 top)."""
    rng = np.random.default_rng(seed)
    ids = rng.permutation(len(survey))
    groups = [ids[i : i + pack_capacity] for i in range(0, len(ids), pack_capacity)]
    return _emit("unstructured", groups, survey, [-1] * len(groups), [-1] * len(groups))


def pack_structured(survey: Survey, pack_capacity: int = 64) -> PackedDataset:
    """One container family per (band, camcol) CCD (Fig. 9 bottom)."""
    tab = survey.meta_table()
    groups: List[np.ndarray] = []
    gband: List[int] = []
    gcamcol: List[int] = []
    for band in range(survey.config.n_bands):
        for camcol in range(survey.config.n_camcols):
            sel = np.where((tab["band_id"] == band) & (tab["camcol"] == camcol))[0]
            for i in range(0, len(sel), pack_capacity):
                groups.append(sel[i : i + pack_capacity])
                gband.append(band)
                gcamcol.append(camcol)
    return _emit("structured", groups, survey, gband, gcamcol)
