"""Brick tessellation: the survey footprint as a fixed grid of coadd cells.

Production surveys do not coadd per ad-hoc query; they tessellate the sky
into fixed *bricks* and materialize a coadd per (brick, band) once
(legacypipe's brick/runbrick design, NSC's healpix tiling).  Serving an
arbitrary query then costs O(bricks touched) — mosaicking cached tiles —
instead of O(images scanned).  This module owns the geometry half of that
contract (DESIGN.md §9); `CoaddEngine.materialize_bricks` and the
`BrickStore` own the execution/storage half.

The bitwise-parity contract
---------------------------
Every brick is a tile of ONE global TAN lattice: a single `WCS` anchored at
the footprint center, ``scale = brick_deg / brick_npix`` deg/px, covering
``n_rows x n_cols`` bricks of ``brick_npix`` pixels each.  A brick's output
grid is computed by running the *global* pixel indices of its tile through
`pixel_to_sky` in float64 and casting to float32 — the exact arithmetic
`mapper.query_grid_sky` performs — so the grid of any window of bricks is
bitwise-identical to the concatenation of its tiles' grids.  Because an
image whose footprint misses a tile contributes *exact zeros* at every tile
pixel (the masked-discard contract, DESIGN.md §3), and per-pack partials
accumulate in the same pack/slot order either way, the mosaic of per-brick
scans equals one fresh scan of the whole window bitwise.  That is the
parity `engine.run(..., use_bricks=True)` promises against
`engine.run_window` whenever a query is brick-aligned (`decompose`), and
tests pin with `assert_array_equal`.

Brick *plan* bounds are the true sky bounding box of the tile's pixel grid
(TAN distortion makes that differ from the nominal ``ra0 + c*brick_deg``
box by up to ~1e-3 deg across a few degrees), padded outward by half an
output pixel: any image contributing at a tile pixel then intersects the
brick's query box with a margin far above float32 rounding, so brick plans
accept a superset of the contributors — the extras contribute exact zeros.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.geometry import WCS, boxes_intersect, pixel_to_sky
from repro.core.query import CoaddQuery


@dataclasses.dataclass(frozen=True)
class BrickCover:
    """A brick-aligned query footprint: a square block of lattice bricks."""

    grid: "BrickGrid"
    band: str
    r0: int
    r1: int
    c0: int
    c1: int

    @property
    def k(self) -> int:
        """Block side length in bricks (square by construction)."""
        return self.r1 - self.r0

    @property
    def bricks(self) -> List[Tuple[int, int]]:
        """Covered (row, col) cells, row-major — the mosaic tile order."""
        return [
            (r, c)
            for r in range(self.r0, self.r1)
            for c in range(self.c0, self.c1)
        ]

    @property
    def tag(self) -> Tuple[str, int, int, int, int]:
        """Hashable identity of this cover — the serving layer's popularity
        accounting key (DESIGN.md §10): per-window hit/miss counts decide
        what to materialize next and what the cost-aware LRU should pin."""
        return (self.band, self.r0, self.r1, self.c0, self.c1)


@dataclasses.dataclass(frozen=True)
class BrickGrid:
    """Deterministic tessellation of a sky rectangle into coadd bricks.

    ``(ra0, dec0)`` is the lattice's lower-left corner (nominal degrees);
    bricks are ``brick_deg`` on a side, ``brick_npix`` output pixels each,
    ``n_rows`` strips of ``n_cols`` bricks.  Brick (r, c) nominally spans
    ``[ra0 + c*brick_deg, ra0 + (c+1)*brick_deg)`` x the analogous dec
    interval — half-open, so the nominal boxes partition the lattice
    rectangle with no gaps and no double cover (property-tested).
    """

    ra0: float
    dec0: float
    brick_deg: float
    brick_npix: int
    n_rows: int
    n_cols: int

    # ----- construction -----
    @staticmethod
    def for_bounds(
        ra0: float,
        dec0: float,
        ra_span: float,
        dec_span: float,
        brick_deg: float = 0.25,
        brick_npix: int = 64,
    ) -> "BrickGrid":
        """Smallest lattice of whole bricks covering the given rectangle."""
        if brick_deg <= 0 or brick_npix <= 0:
            raise ValueError(
                f"brick_deg and brick_npix must be positive, got "
                f"{brick_deg}, {brick_npix}"
            )
        if ra_span <= 0 or dec_span <= 0:
            raise ValueError(
                f"footprint spans must be positive, got {ra_span}, {dec_span}"
            )
        # ceil with a relative epsilon so an exact multiple does not gain a
        # spurious extra row to float division noise.
        n_cols = int(np.ceil(ra_span / brick_deg - 1e-9))
        n_rows = int(np.ceil(dec_span / brick_deg - 1e-9))
        return BrickGrid(ra0, dec0, brick_deg, brick_npix,
                         max(n_rows, 1), max(n_cols, 1))

    @staticmethod
    def for_survey(config, brick_deg: float = 0.25,
                   brick_npix: int = 64) -> "BrickGrid":
        """Lattice covering a `SurveyConfig`'s nominal footprint."""
        return BrickGrid.for_bounds(
            config.ra_start,
            config.dec_min,
            config.ra_span,
            config.n_camcols * config.camcol_dec_deg,
            brick_deg,
            brick_npix,
        )

    # ----- lattice geometry -----
    @property
    def scale(self) -> float:
        """Output pixel scale, deg/px — uniform across the lattice."""
        return self.brick_deg / self.brick_npix

    @property
    def n_bricks(self) -> int:
        return self.n_rows * self.n_cols

    def lattice_wcs(self) -> WCS:
        """The single global TAN system every brick grid is a tile of."""
        w = self.n_cols * self.brick_npix
        h = self.n_rows * self.brick_npix
        return WCS(
            crval=(
                self.ra0 + 0.5 * self.n_cols * self.brick_deg,
                self.dec0 + 0.5 * self.n_rows * self.brick_deg,
            ),
            crpix=((w - 1) / 2.0, (h - 1) / 2.0),
            cd=((self.scale, 0.0), (0.0, self.scale)),
        )

    def _window_sky64(
        self, r0: int, r1: int, c0: int, c1: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Float64 sky coords of a brick window's pixel grid.

        Uses *global* lattice pixel indices, so any window slice and any
        single brick produce bitwise-identical values where they overlap —
        the foundation of the mosaic parity contract.
        """
        self._check_window(r0, r1, c0, c1)
        b = self.brick_npix
        g = self.lattice_wcs().to_vector().astype(np.float64)
        xs, ys = np.meshgrid(
            np.arange(c0 * b, c1 * b, dtype=np.float64),
            np.arange(r0 * b, r1 * b, dtype=np.float64),
        )
        return pixel_to_sky(xs, ys, g)

    def window_sky(
        self, r0: int, r1: int, c0: int, c1: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Float32 output grid (ra, dec) of a brick window — the
        `CoaddPlan.grid_sky` override for window-fresh and brick scans."""
        ra, dec = self._window_sky64(r0, r1, c0, c1)
        return ra.astype(np.float32), dec.astype(np.float32)

    def brick_sky(self, row: int, col: int) -> Tuple[np.ndarray, np.ndarray]:
        """One brick's (brick_npix, brick_npix) output grid."""
        return self.window_sky(row, row + 1, col, col + 1)

    def window_bounds(
        self, r0: int, r1: int, c0: int, c1: int
    ) -> Tuple[float, float, float, float]:
        """True sky bbox of a window's pixel grid, padded half a pixel out.

        The pad guarantees every image contributing flux at a window pixel
        intersects this box with margin well above float32 rounding; the
        extra images an inflated box admits contribute exact zeros.
        """
        ra, dec = self._window_sky64(r0, r1, c0, c1)
        pad = 0.5 * self.scale
        return (
            float(ra.min()) - pad,
            float(ra.max()) + pad,
            float(dec.min()) - pad,
            float(dec.max()) + pad,
        )

    def brick_bounds(self, row: int, col: int) -> Tuple[float, float, float, float]:
        return self.window_bounds(row, row + 1, col, col + 1)

    def nominal_box(self, row: int, col: int) -> Tuple[float, float, float, float]:
        """Nominal (ra_min, ra_max, dec_min, dec_max) cell — half-open
        partition semantics; region filters intersect against this."""
        return (
            self.ra0 + col * self.brick_deg,
            self.ra0 + (col + 1) * self.brick_deg,
            self.dec0 + row * self.brick_deg,
            self.dec0 + (row + 1) * self.brick_deg,
        )

    # ----- queries -----
    def window_query(
        self, r0: int, r1: int, c0: int, c1: int, band: str
    ) -> CoaddQuery:
        """The canonical brick-aligned query for a square window of bricks.

        Queries built here (and only these) decompose back into their
        brick cover; the output grid is the lattice window, threaded to the
        executor as a plan grid override.
        """
        self._check_window(r0, r1, c0, c1)
        if r1 - r0 != c1 - c0:
            raise ValueError(
                f"brick windows must be square, got {r1 - r0}x{c1 - c0}"
            )
        ra_min, ra_max, dec_min, dec_max = self.window_bounds(r0, r1, c0, c1)
        return CoaddQuery(
            band=band,
            ra_bounds=(ra_min, ra_max),
            dec_bounds=(dec_min, dec_max),
            npix=(r1 - r0) * self.brick_npix,
        )

    def brick_query(self, row: int, col: int, band: str) -> CoaddQuery:
        """The materialization query for one (brick, band) cell."""
        return self.window_query(row, row + 1, col, col + 1, band)

    def decompose(self, query: CoaddQuery) -> Optional[BrickCover]:
        """The brick cover of a query, or None when it is not brick-aligned.

        Alignment — the "brick and query parameters agree" half of the
        parity contract — means: no time bounds (bricks stack every epoch),
        npix an exact square multiple of ``brick_npix``, and bounds equal
        (to 1e-6 deg, ~4 mas — far below the pixel scale) to the canonical
        `window_query` of some in-lattice block.  Anything else returns
        None and `run(use_bricks=True)` falls back to the ordinary path.
        """
        if query.time_bounds is not None:
            return None
        k, rem = divmod(query.npix, self.brick_npix)
        if rem or k == 0:
            return None
        # Invert the nominal lattice position, then verify exactly: the true
        # bbox deviates from nominal by TAN distortion (~1e-3 deg) plus the
        # half-pixel pad, both far below half a brick.
        pad = 0.5 * self.scale
        c0 = int(round((query.ra_bounds[0] + pad - self.ra0) / self.brick_deg))
        r0 = int(round((query.dec_bounds[0] + pad - self.dec0) / self.brick_deg))
        if not (0 <= r0 and r0 + k <= self.n_rows
                and 0 <= c0 and c0 + k <= self.n_cols):
            return None
        cand = self.window_query(r0, r0 + k, c0, c0 + k, query.band)
        if not np.allclose(cand.bounds, query.bounds, rtol=0.0, atol=1e-6):
            return None
        return BrickCover(self, query.band, r0, r0 + k, c0, c0 + k)

    def bricks(
        self, region: Optional[Tuple[Tuple[float, float], Tuple[float, float]]] = None
    ) -> List[Tuple[int, int]]:
        """All (row, col) cells, optionally only those whose nominal box
        intersects ``region = (ra_bounds, dec_bounds)`` — the
        `materialize_bricks(region=...)` filter."""
        cells = [
            (r, c) for r in range(self.n_rows) for c in range(self.n_cols)
        ]
        if region is None:
            return cells
        (ra_lo, ra_hi), (dec_lo, dec_hi) = region
        box = (ra_lo, ra_hi, dec_lo, dec_hi)
        return [
            (r, c) for (r, c) in cells
            if boxes_intersect(self.nominal_box(r, c), box)
        ]

    def locate(self, ra: float, dec: float) -> Optional[Tuple[int, int]]:
        """The unique cell whose half-open nominal box contains a point,
        or None outside the lattice (the no-double-cover witness)."""
        c = int(np.floor((ra - self.ra0) / self.brick_deg))
        r = int(np.floor((dec - self.dec0) / self.brick_deg))
        if 0 <= r < self.n_rows and 0 <= c < self.n_cols:
            return (r, c)
        return None

    def _check_window(self, r0: int, r1: int, c0: int, c1: int) -> None:
        if not (0 <= r0 < r1 <= self.n_rows and 0 <= c0 < c1 <= self.n_cols):
            raise ValueError(
                f"window rows [{r0},{r1}) cols [{c0},{c1}) outside lattice "
                f"{self.n_rows}x{self.n_cols}"
            )


__all__ = ["BrickCover", "BrickGrid"]
