"""Synthetic SDSS Stripe-82-like survey.

The paper's testbed is a 100k-image / 600GB subset of SDSS Stripe 82: a
drift-scan survey whose 30-CCD camera (5 bandpass rows x 6 camcol strips,
Fig. 3) tiles a +-1.25 deg declination stripe with ~75-visit coverage
(Fig. 4).  We generate a seeded, fully deterministic miniature with the same
*structure* — that structure (band rows, camcol strips, repeated runs over
the same RA window) is exactly what the paper's prefilters exploit, so the
synthetic survey preserves every property the experiments measure:

* images belong to (run, camcol, band, field);
* camcol determines a declination strip (single-axis spatial prefilter);
* fields advance along RA within a run; runs revisit the same RA window with
  small dec jitter (coverage depth ~= n_runs);
* each image has its own TAN WCS with small per-run rotation jitter;
* pixels = point sources from a *global* seeded catalog + background + noise,
  so overlapping images see the same sky (coaddition is meaningful: SNR of
  the stack grows ~ sqrt(depth), Fig. 2).

Everything is numpy on the host — the survey plays the role of the FITS
archive; packing it into device-resident containers is `seqfile.py`'s job.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.geometry import WCS, image_bounds
from repro.core.query import BANDS


@dataclasses.dataclass(frozen=True)
class SurveyConfig:
    n_runs: int = 8                 # epochs revisiting the stripe
    n_camcols: int = 6              # camera columns = dec strips (Fig. 3)
    n_bands: int = 5                # u, g, r, i, z rows
    n_fields: int = 12              # fields along RA per (run, camcol, band)
    height: int = 32                # image rows (dec)
    width: int = 32                 # image cols (ra)
    ra_start: float = 37.0          # deg; the paper's window is RA 37..40
    field_ra_deg: float = 0.25      # RA span of one field
    camcol_dec_deg: float = 0.4     # dec span of one camcol strip
    dec_center: float = 0.0         # stripe center (Stripe 82: equatorial)
    n_sources: int = 600            # global point-source catalog size
    source_flux_max: float = 100.0
    psf_sigma_px: float = 1.2
    # Measured-PSF calibration products (paper footnote 2): every image gets
    # an empirical PSF stamp — an elliptical Moffat at the run's seeing with
    # per-image ellipticity jitter — the way production pipelines carry a
    # fitted PSF model per exposure.  `moffat_beta=None` degrades the stamps
    # to circular Gaussians (the closure-testable case); `psf_stamps=False`
    # drops them entirely, which is what exercises the engine's separable
    # Gaussian fallback.
    psf_stamps: bool = True
    psf_stamp_size: int = 13        # odd tap grid; also the kernel-bank width
    moffat_beta: Optional[float] = 3.5
    psf_ellip_jitter: float = 0.08  # per-image |e| scale (e1, e2 components)
    background: float = 10.0
    noise_sigma: float = 3.0
    rotation_jitter_deg: float = 0.4
    pointing_jitter_frac: float = 0.05
    seed: int = 82

    @property
    def n_images(self) -> int:
        return self.n_runs * self.n_camcols * self.n_bands * self.n_fields

    @property
    def ra_span(self) -> float:
        return self.n_fields * self.field_ra_deg

    @property
    def dec_min(self) -> float:
        return self.dec_center - 0.5 * self.n_camcols * self.camcol_dec_deg


@dataclasses.dataclass
class SurveyImage:
    """One CCD frame + its metadata (a FITS file, morally)."""

    image_id: int
    run: int
    camcol: int            # 0-based camera column (dec strip)
    band_id: int           # 0..4 -> u g r i z
    field: int
    t_obs: float
    wcs: WCS
    bounds: tuple          # (ra_min, ra_max, dec_min, dec_max)
    pixels: np.ndarray     # (H, W) float32
    psf_sigma: float = 1.2  # per-image seeing (px); drives PSF matching
    psf_stamp: Optional[np.ndarray] = None  # (S, S) measured PSF model, sum 1

    @property
    def band(self) -> str:
        return BANDS[self.band_id]


@dataclasses.dataclass
class Survey:
    config: SurveyConfig
    images: List[SurveyImage]
    catalog_ra: np.ndarray
    catalog_dec: np.ndarray
    catalog_flux: np.ndarray   # (n_sources, n_bands)

    def __len__(self) -> int:
        return len(self.images)

    def meta_table(self) -> dict:
        """Columnar metadata for the whole archive (the prefilters' input)."""
        n = len(self.images)
        tab = {
            "image_id": np.arange(n, dtype=np.int32),
            "run": np.array([im.run for im in self.images], np.int32),
            "camcol": np.array([im.camcol for im in self.images], np.int32),
            "band_id": np.array([im.band_id for im in self.images], np.int32),
            "field": np.array([im.field for im in self.images], np.int32),
            "t_obs": np.array([im.t_obs for im in self.images], np.float32),
            "ra_min": np.array([im.bounds[0] for im in self.images], np.float32),
            "ra_max": np.array([im.bounds[1] for im in self.images], np.float32),
            "dec_min": np.array([im.bounds[2] for im in self.images], np.float32),
            "dec_max": np.array([im.bounds[3] for im in self.images], np.float32),
            "psf_sigma": np.array([im.psf_sigma for im in self.images], np.float32),
            "wcs": np.stack([im.wcs.to_vector() for im in self.images]),
        }
        return tab


def _render_image(
    wcs: WCS,
    height: int,
    width: int,
    cat_ra: np.ndarray,
    cat_dec: np.ndarray,
    cat_flux: np.ndarray,
    psf_sigma: float,
    background: float,
    noise_sigma: float,
    rng: np.random.Generator,
) -> np.ndarray:
    """Render point sources through a Gaussian PSF onto the frame."""
    from repro.core.geometry import sky_to_pixel

    v = wcs.to_vector().astype(np.float64)
    sx, sy = sky_to_pixel(cat_ra, cat_dec, v)
    margin = 4.0 * psf_sigma
    keep = (
        (sx > -margin) & (sx < width - 1 + margin) &
        (sy > -margin) & (sy < height - 1 + margin)
    )
    img = np.full((height, width), background, dtype=np.float64)
    if keep.any():
        xs = sx[keep]
        ys = sy[keep]
        fl = cat_flux[keep]
        yy, xx = np.mgrid[0:height, 0:width]
        # (n_kept, H, W) Gaussian splats; fine at miniature scale.
        d2 = (xx[None] - xs[:, None, None]) ** 2 + (yy[None] - ys[:, None, None]) ** 2
        img += (fl[:, None, None] * np.exp(-0.5 * d2 / psf_sigma**2)).sum(0)
    img += rng.normal(0.0, noise_sigma, size=img.shape)
    return img.astype(np.float32)


def render_psf_stamp(
    sigma: float,
    size: int,
    beta: Optional[float] = None,
    e1: float = 0.0,
    e2: float = 0.0,
) -> np.ndarray:
    """(size, size) unit-sum empirical PSF stamp, centered.

    ``beta=None`` renders a circular/elliptical Gaussian; otherwise an
    elliptical Moffat whose FWHM matches a Gaussian of width ``sigma`` —
    Moffat wings are the canonical non-Gaussianity of real seeing, which is
    exactly what makes the Fourier least-squares homogenization kernel a
    different object from the closed-form Gaussian matching kernel.
    The (e1, e2) shear components tilt the quadratic form at unit area.
    """
    if size % 2 == 0:
        raise ValueError(f"stamp size must be odd, got {size}")
    c = (size - 1) / 2.0
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64)
    xx -= c
    yy -= c
    # Unit-determinant shear: |e| < 1 keeps the form positive definite.
    r2 = (1 + e1) * xx**2 + (1 - e1) * yy**2 + 2 * e2 * xx * yy
    r2 /= max(np.sqrt(max(1.0 - e1**2 - e2**2, 1e-6)), 1e-6)
    if beta is None:
        img = np.exp(-0.5 * r2 / max(sigma, 1e-6) ** 2)
    else:
        fwhm = 2.0 * np.sqrt(2.0 * np.log(2.0)) * sigma
        alpha = fwhm / (2.0 * np.sqrt(2.0 ** (1.0 / beta) - 1.0))
        img = (1.0 + r2 / alpha**2) ** (-beta)
    return (img / img.sum()).astype(np.float32)


def make_survey(config: Optional[SurveyConfig] = None) -> Survey:
    cfg = config or SurveyConfig()
    rng = np.random.default_rng(cfg.seed)

    # Global source catalog shared by all epochs (the actual sky).
    cat_ra = rng.uniform(cfg.ra_start, cfg.ra_start + cfg.ra_span, cfg.n_sources)
    cat_dec = rng.uniform(
        cfg.dec_min, cfg.dec_min + cfg.n_camcols * cfg.camcol_dec_deg, cfg.n_sources
    )
    # Power-law-ish fluxes, band-correlated.
    base = rng.pareto(2.0, cfg.n_sources) * cfg.source_flux_max / 10.0
    band_scale = rng.uniform(0.6, 1.4, size=(cfg.n_sources, cfg.n_bands))
    cat_flux = (base[:, None] * band_scale).astype(np.float64)

    ra_scale = cfg.field_ra_deg / cfg.width       # deg / px along RA
    dec_scale = cfg.camcol_dec_deg / cfg.height   # deg / px along Dec

    images: List[SurveyImage] = []
    image_id = 0
    for run in range(cfg.n_runs):
        run_rng = np.random.default_rng(cfg.seed + 1000 + run)
        # Per-run pointing and rotation jitter (astrometric registration is
        # what makes projection non-trivial).
        dec_jit = run_rng.normal(0.0, cfg.pointing_jitter_frac * cfg.camcol_dec_deg)
        ra_phase = run_rng.uniform(-cfg.pointing_jitter_frac, cfg.pointing_jitter_frac) * cfg.field_ra_deg
        theta = np.deg2rad(run_rng.normal(0.0, cfg.rotation_jitter_deg))
        # Per-run seeing: atmospheric conditions vary between epochs, so each
        # run's PSF width jitters around the nominal — this is what makes PSF
        # matching to a common (worst) width a real operation, not a no-op.
        seeing = float(cfg.psf_sigma_px * run_rng.uniform(0.85, 1.35))
        rot = np.array(
            [[np.cos(theta), -np.sin(theta)], [np.sin(theta), np.cos(theta)]]
        )
        cd = rot @ np.array([[ra_scale, 0.0], [0.0, dec_scale]])
        for camcol in range(cfg.n_camcols):
            dec_c = cfg.dec_min + (camcol + 0.5) * cfg.camcol_dec_deg + dec_jit
            for field in range(cfg.n_fields):
                ra_c = cfg.ra_start + (field + 0.5) * cfg.field_ra_deg + ra_phase
                wcs = WCS(
                    crval=(ra_c, dec_c),
                    crpix=((cfg.width - 1) / 2.0, (cfg.height - 1) / 2.0),
                    cd=((cd[0, 0], cd[0, 1]), (cd[1, 0], cd[1, 1])),
                )
                bounds = image_bounds(wcs, cfg.height, cfg.width)
                for band_id in range(cfg.n_bands):
                    pix_rng = np.random.default_rng(
                        cfg.seed + 7 * image_id + 13 * band_id + 1
                    )
                    # Separate stream: stamp jitter must not perturb the
                    # pixel noise draws existing surveys are seeded on.
                    # Sequence-seeded (not an affine scalar formula) so it
                    # can never collide with the pixel RNG's
                    # ``seed + 7*id + 13*band + 1`` lattice.
                    stamp = None
                    if cfg.psf_stamps:
                        stamp_rng = np.random.default_rng(
                            (cfg.seed, 2, image_id)
                        )
                        e1, e2 = stamp_rng.normal(
                            0.0, cfg.psf_ellip_jitter, size=2
                        ).clip(-0.3, 0.3)
                        stamp = render_psf_stamp(
                            seeing, cfg.psf_stamp_size, cfg.moffat_beta,
                            float(e1), float(e2),
                        )
                    pixels = _render_image(
                        wcs,
                        cfg.height,
                        cfg.width,
                        cat_ra,
                        cat_dec,
                        cat_flux[:, band_id],
                        seeing,
                        cfg.background,
                        cfg.noise_sigma,
                        pix_rng,
                    )
                    images.append(
                        SurveyImage(
                            image_id=image_id,
                            run=run,
                            camcol=camcol,
                            band_id=band_id,
                            field=field,
                            t_obs=float(run * 100 + field),
                            wcs=wcs,
                            bounds=bounds,
                            pixels=pixels,
                            psf_sigma=seeing,
                            psf_stamp=stamp,
                        )
                    )
                    image_id += 1
    return Survey(cfg, images, cat_ra, cat_dec, cat_flux)
