"""WCS geometry: gnomonic (TAN) projection, pixel<->sky mapping, bounds.

The paper registers SDSS FITS frames onto a query's common coordinate system
("Astrometry/interpolation", Algorithm 2 line 8).  SDSS frames carry a TAN
(tangent-plane / gnomonic) WCS; we implement the same projection here, in a
form that is vectorizable under ``jax.vmap`` and differentiable (the warp is
pure arithmetic).

Conventions
-----------
* Sky coordinates (ra, dec) in **degrees**; Stripe-82-like footprints stay
  far from RA wrap-around, which we do not handle (documented in DESIGN.md).
* A :class:`WCS` is parameterized by ``crval`` (sky at reference pixel),
  ``crpix`` (reference pixel, 0-based), and a 2x2 ``cd`` matrix in
  degrees/pixel mapping pixel offsets to intermediate world coordinates.
* Pixel coordinates are (x, y) = (column, row), 0-based, following FITS
  minus the 1-offset.

Everything here works on both numpy arrays (host-side metadata math used by
the prefilter) and jnp arrays (device-side warp), because only ``*``, ``+``
and trig are used.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

DEG2RAD = np.pi / 180.0
RAD2DEG = 180.0 / np.pi

# Flat vector layout used when WCS parameters ride along as a per-image
# feature vector inside packed datasets:
#   [crval_ra, crval_dec, crpix_x, crpix_y, cd11, cd12, cd21, cd22]
WCS_NPARAMS = 8


@dataclasses.dataclass(frozen=True)
class WCS:
    """Tangent-plane world coordinate system for one image or query grid."""

    crval: Tuple[float, float]  # (ra0, dec0) degrees
    crpix: Tuple[float, float]  # (x0, y0) pixels
    cd: Tuple[Tuple[float, float], Tuple[float, float]]  # deg / pixel

    def to_vector(self) -> np.ndarray:
        (cd11, cd12), (cd21, cd22) = self.cd
        return np.array(
            [
                self.crval[0],
                self.crval[1],
                self.crpix[0],
                self.crpix[1],
                cd11,
                cd12,
                cd21,
                cd22,
            ],
            dtype=np.float32,
        )

    @staticmethod
    def from_vector(v) -> "WCS":
        v = np.asarray(v, dtype=np.float64)
        return WCS(
            crval=(float(v[0]), float(v[1])),
            crpix=(float(v[2]), float(v[3])),
            cd=((float(v[4]), float(v[5])), (float(v[6]), float(v[7]))),
        )


# ---------------------------------------------------------------------------
# Gnomonic projection (all-array math; works with numpy or jax.numpy)
# ---------------------------------------------------------------------------


def sky_to_tangent(ra, dec, ra0, dec0):
    """Project sky coords onto the tangent plane at (ra0, dec0).

    Returns intermediate world coordinates (xi, eta) in **degrees** —
    the standard TAN "native" coordinates.
    """
    xp = jnp if isinstance(ra, jnp.ndarray) else np
    ra_r = ra * DEG2RAD
    dec_r = dec * DEG2RAD
    ra0_r = ra0 * DEG2RAD
    dec0_r = dec0 * DEG2RAD
    cosc = xp.sin(dec0_r) * xp.sin(dec_r) + xp.cos(dec0_r) * xp.cos(dec_r) * xp.cos(
        ra_r - ra0_r
    )
    xi = xp.cos(dec_r) * xp.sin(ra_r - ra0_r) / cosc
    eta = (
        xp.cos(dec0_r) * xp.sin(dec_r)
        - xp.sin(dec0_r) * xp.cos(dec_r) * xp.cos(ra_r - ra0_r)
    ) / cosc
    return xi * RAD2DEG, eta * RAD2DEG


def tangent_to_sky(xi, eta, ra0, dec0):
    """Inverse gnomonic: tangent-plane (xi, eta) degrees -> (ra, dec) degrees."""
    xp = jnp if isinstance(xi, jnp.ndarray) else np
    xi_r = xi * DEG2RAD
    eta_r = eta * DEG2RAD
    ra0_r = ra0 * DEG2RAD
    dec0_r = dec0 * DEG2RAD
    rho = xp.sqrt(xi_r**2 + eta_r**2)
    c = xp.arctan(rho)
    cos_c = xp.cos(c)
    sin_c = xp.sin(c)
    # Guard rho == 0 (point at tangent center).
    safe_rho = xp.where(rho == 0, 1.0, rho)
    dec_r = xp.arcsin(
        cos_c * xp.sin(dec0_r) + eta_r * sin_c * xp.cos(dec0_r) / safe_rho
    )
    ra_r = ra0_r + xp.arctan2(
        xi_r * sin_c,
        safe_rho * xp.cos(dec0_r) * cos_c - eta_r * xp.sin(dec0_r) * sin_c,
    )
    dec_r = xp.where(rho == 0, dec0_r, dec_r)
    ra_r = xp.where(rho == 0, ra0_r, ra_r)
    return ra_r * RAD2DEG, dec_r * RAD2DEG


def pixel_to_sky(x, y, wcs_vec):
    """Pixel coords -> sky (ra, dec) via a WCS parameter vector (see layout)."""
    ra0, dec0 = wcs_vec[0], wcs_vec[1]
    x0, y0 = wcs_vec[2], wcs_vec[3]
    cd11, cd12, cd21, cd22 = wcs_vec[4], wcs_vec[5], wcs_vec[6], wcs_vec[7]
    dx = x - x0
    dy = y - y0
    xi = cd11 * dx + cd12 * dy
    eta = cd21 * dx + cd22 * dy
    return tangent_to_sky(xi, eta, ra0, dec0)


def sky_to_pixel(ra, dec, wcs_vec):
    """Sky (ra, dec) -> pixel coords via a WCS parameter vector."""
    ra0, dec0 = wcs_vec[0], wcs_vec[1]
    x0, y0 = wcs_vec[2], wcs_vec[3]
    cd11, cd12, cd21, cd22 = wcs_vec[4], wcs_vec[5], wcs_vec[6], wcs_vec[7]
    xi, eta = sky_to_tangent(ra, dec, ra0, dec0)
    det = cd11 * cd22 - cd12 * cd21
    dx = (cd22 * xi - cd12 * eta) / det
    dy = (-cd21 * xi + cd11 * eta) / det
    return dx + x0, dy + y0


# ---------------------------------------------------------------------------
# Footprints and intersections (host-side metadata math)
# ---------------------------------------------------------------------------


def image_bounds(wcs: WCS, height: int, width: int) -> Tuple[float, float, float, float]:
    """RA/Dec bounding box of an image (min_ra, max_ra, min_dec, max_dec)."""
    xs = np.array([0.0, width - 1.0, 0.0, width - 1.0])
    ys = np.array([0.0, 0.0, height - 1.0, height - 1.0])
    ra, dec = pixel_to_sky(xs, ys, wcs.to_vector().astype(np.float64))
    return float(ra.min()), float(ra.max()), float(dec.min()), float(dec.max())


def boxes_intersect(a, b) -> bool:
    """Axis-aligned RA/Dec box intersection. Boxes are (ra0, ra1, dec0, dec1)."""
    return not (a[1] < b[0] or b[1] < a[0] or a[3] < b[2] or b[3] < a[2])


def make_grid_wcs(center_ra: float, center_dec: float, npix: int, fov_deg: float) -> WCS:
    """Query-grid WCS: square TAN grid of ``npix`` pixels spanning ``fov_deg``."""
    scale = fov_deg / npix  # deg / pixel
    return WCS(
        crval=(center_ra, center_dec),
        crpix=((npix - 1) / 2.0, (npix - 1) / 2.0),
        # RA increases to the left on the sky by convention; keep it simple
        # and make +x -> +RA so tests read naturally.
        cd=((scale, 0.0), (0.0, scale)),
    )
