"""CoaddEngine: the paper's MapReduce coaddition job, end to end.

Implements all six input-format strategies of Table 1 / Table 2 so the
benchmarks can reproduce the paper's comparisons measurably:

  1. ``raw_fits``                 — per-file dispatch, no prefilter (the
                                    paper only estimated this row; we measure)
  2. ``raw_fits_prefiltered``     — glob (band x camcol) prefilter, then
                                    per-file dispatch            (§4.1.1)
  3. ``unstructured_seq``         — packed containers, random layout; no
                                    pruning possible; all packs read (§4.1.2)
  4. ``structured_seq_prefiltered``— containers keyed by (band, camcol);
                                    container-level glob pruning (§4.1.3)
  5. ``sql_unstructured``         — exact spatial-index selection gathered
                                    from the unstructured containers (§4.1.4)
  6. ``sql_structured``           — exact selection gathered from structured
                                    containers (better locality -> fewer
                                    containers touched)          (§4.1.4)

Plan/execute split (DESIGN.md §4): each method is a pure *planner*
(``plan_<method>(query) -> CoaddPlan``: layout + (P, cap) slot gate + query
vector + locate stats — the paper's job-init phase) feeding one of three
*executors* over resident data:

* ``execute(plan)``          — one jitted `lax.scan` over the device-resident
                               layout (PR 1's one-dispatch path).
* ``run_batch(queries, m)``  — stacks same-layout plans and vmaps the scan
                               over the query axis: K queries, ONE dispatch
                               (the paper's Fig. 5 multi-query amortization).
* ``run_distributed(...)``   — the production path: the structured layout is
                               sharded onto the mesh **once**
                               (`MeshResidentDataset`, cached per
                               (layout, mesh)); each job ships only slot
                               gates + query vectors + grids, maps locally
                               under `shard_map`, and reduces by psum +
                               reduce-scatter (see `reducer.py`).

When ``match_psf_sigma`` is set, the map stage first convolves every image
to that common PSF width using a host-precomputed per-slot kernel bank —
measured-PSF homogenization kernels (`psf.homogenization_bank`, Fourier
least squares over the survey's empirical stamps) when the layout carries
stamps, the separable Gaussian bank (`psf.matching_kernel_bank` over
``psf_sigma``) otherwise — threaded as a plain operand through the XLA
mapper, the Pallas ``coadd_fused`` kernel (1-D banded or 2-D banded-matmul
variants), and the distributed mesh job.  On the XLA path the matching
convolution is query-independent, so by default it runs ONCE per
(layout, target) at residency time and the *matched pixels* are cached
under the device budget (`matched_pixel_cache`, DESIGN.md §7); the Pallas
path keeps the documented in-kernel recompute instead (fusion trades MXU
for HBM).

Sparse execution (DESIGN.md §5, default on): the planner's gate also sets
the *scan extent*.  Each executor gathers just the packs the gate opens out
of the resident arrays (``jnp.take`` over a budget-bucketed pack-index
vector) and scans the compacted result, so map cost tracks ``packs_gated``
rather than the layout size; the degenerate per-file layout is additionally
reblocked into dense super-packs at residency time.  ``sparse=False``
restores the dense masked-discard scan over every pack.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from collections import OrderedDict
from functools import partial
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import mapper, psf, reducer
from repro.core.bricks import BrickCover, BrickGrid
from repro.core.durable import BrickSpill, JournalStore
from repro.core.faults import ChaosInjector, PoisonedChunkError
from repro.core.jobtracker import (
    BrickTask,
    FaultCounters,
    MaterializeReport,
    MaterializeTracker,
    WindowTracker,
)
from repro.core.plan import (
    CoaddPlan,
    ScanWindow,
    SparseScanIndex,
    compact_gate,
    compact_gates,
    compact_window_gate,
    compact_window_gates,
    grid_digest,
    sparse_pack_index,
    stack_plans,
    union_sparse_index,
    window_schedule,
)
from repro.core.prefilter import (
    SpatialIndex,
    camcol_dec_table,
    glob_file_mask,
    glob_pack_mask,
)
from repro.core.query import CoaddQuery
from repro.core.seqfile import (
    COST_MATCHED_CHUNK,
    COST_RAW_CHUNK,
    BrickMeta,
    BrickStore,
    DevicePackedDataset,
    MeshResidentDataset,
    PackedDataset,
    ResidencyManager,
    SlotRemap,
    pack_per_file,
    pack_structured,
    pack_unstructured,
)
from repro.core.survey import Survey
from repro.distributed.sharding import (
    shard_count,
    shard_local_compaction,
    shard_map_compat,
)
from repro.kernels.warp import ops as warp_ops

METHODS = (
    "raw_fits",
    "raw_fits_prefiltered",
    "unstructured_seq",
    "structured_seq_prefiltered",
    "sql_unstructured",
    "sql_structured",
)


@dataclasses.dataclass
class JobStats:
    method: str
    files_considered: int          # mapper input records (Table 2)
    files_contributing: int        # actual coverage
    packs_touched: int             # "mapper objects" locality proxy (§4.1.4):
                                   #   distinct planning-layout containers the
                                   #   gate opens; `run_distributed` reports
                                   #   mesh shard slabs touched by the flat
                                   #   gate (pack identity is lost there)
    t_locate_s: float              # job-init: prefilter/index/gather ("RPC")
    t_map_reduce_s: float          # device compute
    t_total_s: float
    dispatches: int = 1            # jitted device dispatches for this query
    # Sparse-execution accounting (DESIGN.md §5) — gated vs scanned work:
    packs_gated: int = 0           # execution-layout packs the gate opens
    packs_scanned: int = 0         # pack-axis scan steps actually executed;
                                   #   additive: batched/distributed jobs
                                   #   attribute the job's scan work to the
                                   #   first result (like dispatches), and
                                   #   run_distributed counts all shards
                                   #   (n_shards * scan_budget)
    scan_budget: int = 0           # static per-program bucket the scan
                                   #   compiled for (n_packs if dense; the
                                   #   per-shard budget in run_distributed);
                                   #   descriptive, not additive — every
                                   #   result in a job reports it
    # Streaming-residency accounting (DESIGN.md §6).  Zero on the eager
    # path (no device budget configured); attribution follows the same
    # rules as above — windows is descriptive, chunk counters are additive
    # (batched/distributed jobs put them on the first result).
    windows: int = 0               # residency windows the query scanned
    chunk_uploads: int = 0         # chunks uploaded during this call (misses)
    residency_hits: int = 0        # chunks served already-resident
    residency_evictions: int = 0   # LRU evictions this call forced
    # Matched-pixel cache accounting (DESIGN.md §7) — device-side PSF
    # convolutions this call built vs reused; zero when matching is off,
    # the Pallas in-kernel path runs, or the cache is disabled.
    matched_cache_builds: int = 0  # (layout, target) matched arrays built
    matched_cache_hits: int = 0    # matched arrays served already-resident
    # True residency high-water mark — the honest version of the advisory
    # budget accounting; descriptive, not additive.  Streaming: budget +
    # one in-flight window's operands, matched-pixel cache included.
    # Eager: also counts the unmanaged whole-layout uploads and device
    # banks, so matched mode reports raw + matched copies both resident.
    peak_resident_bytes: int = 0
    # Fault-domain accounting (DESIGN.md §8) — what the WindowTracker did
    # to finish this query.  Counters are additive (batched jobs put them
    # on the first result); ``partial``/``uncovered_packs`` are
    # descriptive and reported on every result of a job.  All zero/False
    # on the eager path and on clean tracked runs.
    retries: int = 0               # failed attempts that were re-executed
    speculative_windows: int = 0   # straggler backups launched (digest-verified)
    quarantined_packs: int = 0     # packs gated out after persistent poison
    resumed_windows: int = 0       # journal hits replayed instead of re-run
    partial: bool = False          # True when quarantine removed coverage
    uncovered_packs: Tuple[int, ...] = ()  # exec-layout packs quarantined out
    requarantine_released: int = 0 # packs restored by digest re-verification
                                   #   (`reverify_quarantined`) since the
                                   #   previous streaming result; additive
    # Brick-serving accounting (DESIGN.md §9) — how `run(use_bricks=True)`
    # covered this query.  All additive (a mosaic is one result); zero on
    # every brick-free path.  ``bricks_hit`` counts tiles served from the
    # device tier, ``bricks_spilled`` tiles re-uploaded from the host tier
    # after LRU pressure dropped their device replica, ``bricks_missed``
    # tiles that had to be freshly materialized inline, and
    # ``residual_packs_scanned`` the streaming scan work those misses paid
    # (the warm path's number is 0 — that gap is the whole point).
    bricks_hit: int = 0
    bricks_missed: int = 0
    bricks_spilled: int = 0
    residual_packs_scanned: int = 0
    # Robust-reduction accounting (DESIGN.md §11): which reduction variant
    # produced this result ("mean" | "clipped" | "median") and how many
    # monoidal passes over the windows it took (1 on the mean path and on
    # every eager path — the fused program re-scans internally).
    reduce: str = "mean"
    reduce_passes: int = 1


@dataclasses.dataclass
class CoaddResult:
    coadd: np.ndarray
    depth: np.ndarray
    stats: JobStats

    @property
    def normalized(self) -> np.ndarray:
        # Exact masking, no epsilon clamp: robust clip masks make fractional
        # depths (a 0.5-coverage border pixel) routine, and max(depth, 1e-6)
        # would rescale them instead of dividing by the true weight.
        return np.where(
            self.depth > 0, self.coadd / np.where(self.depth > 0, self.depth, 1.0), 0.0
        )


def _query_vec(query: CoaddQuery) -> np.ndarray:
    t0, t1 = query.time_window()
    # Large-but-finite sentinels keep the vector finite for jit friendliness.
    t0 = max(t0, -1e30)
    t1 = min(t1, 1e30)
    return np.array(
        [
            float(query.band_id),
            query.ra_bounds[0],
            query.ra_bounds[1],
            query.dec_bounds[0],
            query.dec_bounds[1],
            t0,
            t1,
        ],
        np.float32,
    )


def _accept_from_meta(ints, floats, qvec):
    band_ok = ints["band_id"].astype(jnp.float32) == qvec[0]
    valid = ints["image_id"] >= 0
    ra_ok = (floats["ra_max"] >= qvec[1]) & (floats["ra_min"] <= qvec[2])
    dec_ok = (floats["dec_max"] >= qvec[3]) & (floats["dec_min"] <= qvec[4])
    t_ok = (floats["t_obs"] >= qvec[5]) & (floats["t_obs"] <= qvec[6])
    return band_ok & valid & ra_ok & dec_ok & t_ok


@partial(jax.jit, static_argnames=("use_kernel",))
def _coadd_batch(pixels, wcs, ints, floats, qvec, grid_ra, grid_dec, use_kernel=False):
    """Map+local-reduce one dense batch of images. The jitted inner job."""
    accept = _accept_from_meta(ints, floats, qvec)
    tiles, covs = mapper.map_batch(
        pixels, wcs, accept, grid_ra, grid_dec, use_kernel=use_kernel
    )
    coadd, depth = reducer.reduce_local(tiles, covs)
    return coadd, depth, accept.sum()


def _scan_coadd(
    pixels,       # (P, cap, H, W) device-resident
    wcs,          # (P, cap, 8)
    ints,         # dict of (P, cap) int32
    floats,       # dict of (P, cap) float32
    psf_kernels,  # (P, cap, K) float32 matching-kernel bank, or None
    gate,         # (P, cap) bool — static shape, dynamic values
    qvec,         # (7,)
    grid_ra,      # (Q, Q)
    grid_dec,     # (Q, Q)
    use_kernel,
    block_rows,
    interpret,
    pack_idx=None,  # (G,) int32 — sparse: scan only these packs of the layout
):
    """The whole query in ONE XLA program: scan packs, fuse map+reduce.

    The scan carries (coadd, depth, contributing); each step gates a pack's
    slots by metadata acceptance AND the caller's slot gate, (optionally)
    PSF-matches the slots, projects, and accumulates locally — so the
    (N, Q, Q) tile stack never materializes across packs and the dispatch
    count is 1 regardless of n_packs.  Non-gated slots contribute exact
    zeros (masked SPMD discard, Fig. 6).  Counts come back as device
    scalars: no per-pack host syncs.

    Sparse mode (``pack_idx`` given, DESIGN.md §5): the scan iterates the
    budget-bucketed index vector instead of the pack axis, and each step
    *streams* its pack out of the resident arrays (`mapper.gather_packs`
    with a scalar index) — the gather rides inside the scan, so no
    (G, cap, H, W) compacted copy ever materializes next to the resident
    layout.  ``gate`` must then be the (G, cap) compacted gate.
    """

    def body(carry, px, wv, ints_p, floats_p, kern_p, gate_p):
        coadd, depth, contrib = carry
        accept = _accept_from_meta(ints_p, floats_p, qvec) & gate_p
        if use_kernel:
            c, d = warp_ops.coadd_fused(
                px,
                wv,
                accept.astype(jnp.float32),
                grid_ra,
                grid_dec,
                psf_kernels=kern_p,
                block_rows=block_rows,
                interpret=interpret,
            )
        else:
            tiles, covs = mapper.map_batch(
                px, wv, accept, grid_ra, grid_dec, psf_kernels=kern_p
            )
            c, d = reducer.reduce_local(tiles, covs)
        return (coadd + c, depth + d, contrib + accept.sum()), None

    q = grid_ra.shape[0]
    init = (
        jnp.zeros((q, q), jnp.float32),
        jnp.zeros((q, q), jnp.float32),
        jnp.zeros((), jnp.int32),
    )
    (coadd, depth, contrib), _ = _scan_packs(
        body, init, pixels, wcs, ints, floats, psf_kernels, gate, pack_idx
    )
    return coadd, depth, contrib, gate.sum()


def _scan_packs(body, init, pixels, wcs, ints, floats, psf_kernels, gate,
                pack_idx):
    """Shared pack-scan plumbing: dense xs, or sparse streamed gather.

    ``body(carry, px, wv, ints_p, floats_p, kern_p, gate_p)`` is the per-pack
    monoid step; the dense/sparse split (DESIGN.md §5) lives here once so the
    mean scan and every robust pass (§11) iterate packs identically — which
    is what makes their per-pixel accumulation orders, and therefore the
    bitwise streaming/brick parity arguments, line up across reducers.

    Returns ``(carry, ys)``: bodies that emit per-pack outputs (the resident
    warp cache in `_robust_passes`) get them stacked along a leading pack
    axis; monoid-only bodies return None ys.
    """
    if pack_idx is None:
        def step(carry, xs):
            px, wv, ints_p, floats_p, kern_p, gate_p = xs
            return body(carry, px, wv, ints_p, floats_p, kern_p, gate_p)

        xs = (pixels, wcs, ints, floats, psf_kernels, gate)
    else:
        def step(carry, xs):
            i, gate_p = xs
            px, wv, ints_p, floats_p, kern_p = mapper.gather_packs(
                i, pixels, wcs, ints, floats, psf_kernels
            )
            return body(carry, px, wv, ints_p, floats_p, kern_p, gate_p)

        xs = (pack_idx, gate)

    return jax.lax.scan(step, init, xs)


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _coadd_scan(
    pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
    use_kernel=False, block_rows=8, interpret=True,
):
    """One plan against a device-resident layout, as one jitted program."""
    return _scan_coadd(
        pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
        use_kernel, block_rows, interpret,
    )


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _coadd_scan_batch(
    pixels, wcs, ints, floats, psf_kernels, gates, qvecs, grids_ra, grids_dec,
    use_kernel=False, block_rows=8, interpret=True,
):
    """K stacked plans against one resident layout, as ONE jitted program.

    vmaps the scan's gate/qvec/grid axes over the query dimension while the
    resident pack arrays broadcast — the batched multi-query job of paper
    Fig. 5 with zero extra pixel traffic.
    """

    def one(gate, qvec, grid_ra, grid_dec):
        return _scan_coadd(
            pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra,
            grid_dec, use_kernel, block_rows, interpret,
        )

    return jax.vmap(one)(gates, qvecs, grids_ra, grids_dec)


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _coadd_scan_sparse(
    pixels, wcs, ints, floats, psf_kernels, pack_idx, gate, qvec, grid_ra,
    grid_dec, use_kernel=False, block_rows=8, interpret=True,
):
    """Sparse plan against a resident layout, still ONE jitted program.

    The scan iterates the budget-bucketed (G,) index vector, streaming each
    gated pack out of the resident arrays per step — G scan steps instead of
    P, no compacted pixel copy.  ``gate`` arrives pre-compacted
    (`plan.compact_gate`), so padding rows are all-False and the
    considered/contributing counts match the dense scan exactly.
    """
    return _scan_coadd(
        pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
        use_kernel, block_rows, interpret, pack_idx=pack_idx,
    )


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _coadd_scan_batch_sparse(
    pixels, wcs, ints, floats, psf_kernels, pack_idx, gates, qvecs, grids_ra,
    grids_dec, use_kernel=False, block_rows=8, interpret=True,
):
    """K stacked plans over the union of their gated packs, ONE program.

    The gather set is the union across queries (`plan.union_sparse_index`);
    the vmapped per-query gates re-select each query's slots within it —
    preserving the K-queries-one-dispatch property while map work scales
    with the union's selectivity.  The index vector is shared (not vmapped):
    every query's scan streams the same G packs.
    """

    def one(gate, qvec, grid_ra, grid_dec):
        return _scan_coadd(
            pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra,
            grid_dec, use_kernel, block_rows, interpret, pack_idx=pack_idx,
        )

    return jax.vmap(one)(gates, qvecs, grids_ra, grids_dec)


# ----- robust reductions: monoidal pass programs (DESIGN.md §11) -----------
#
# Sigma-clipped and median stacks are not accumulate-only monoids, but they
# decompose into passes that are: moments (S0, S1, S2), an optional binapprox
# histogram, and a clip re-scan whose center/radius arrive as fixed operands.
# Each pass below is the same pack scan as `_scan_coadd` with a different
# per-pack monoid, so the streaming windows, journals, and brick tiles reuse
# every existing mechanism — they just run more passes.

def _scan_moments(
    pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
    use_kernel, block_rows, interpret, pack_idx=None,
):
    """Robust pass 1: coverage-weighted moments of the stack, ONE program."""

    def body(carry, px, wv, ints_p, floats_p, kern_p, gate_p):
        s0, s1, s2, contrib = carry
        accept = _accept_from_meta(ints_p, floats_p, qvec) & gate_p
        if use_kernel:
            a0, a1, a2 = warp_ops.coadd_moments(
                px, wv, accept.astype(jnp.float32), grid_ra, grid_dec,
                psf_kernels=kern_p, block_rows=block_rows, interpret=interpret,
            )
        else:
            tiles, covs = mapper.map_batch(
                px, wv, accept, grid_ra, grid_dec, psf_kernels=kern_p
            )
            a0, a1, a2 = reducer.moments_local(tiles, covs)
        return (s0 + a0, s1 + a1, s2 + a2, contrib + accept.sum()), None

    q = grid_ra.shape[0]
    z = jnp.zeros((q, q), jnp.float32)
    init = (z, z, z, jnp.zeros((), jnp.int32))
    (s0, s1, s2, contrib), _ = _scan_packs(
        body, init, pixels, wcs, ints, floats, psf_kernels, gate, pack_idx
    )
    return s0, s1, s2, contrib, gate.sum()


def _scan_hist(
    pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
    lo, inv_w, nbins, use_kernel, block_rows, interpret, pack_idx=None,
):
    """Median round 1: coverage-weighted binapprox histogram, ONE program."""

    def body(hist, px, wv, ints_p, floats_p, kern_p, gate_p):
        accept = _accept_from_meta(ints_p, floats_p, qvec) & gate_p
        if use_kernel:
            h = warp_ops.coadd_hist(
                px, wv, accept.astype(jnp.float32), grid_ra, grid_dec,
                lo, inv_w, nbins=nbins, psf_kernels=kern_p,
                block_rows=block_rows, interpret=interpret,
            )
        else:
            tiles, covs = mapper.map_batch(
                px, wv, accept, grid_ra, grid_dec, psf_kernels=kern_p
            )
            h = reducer.hist_local(tiles, covs, lo, inv_w, nbins)
        return hist + h, None

    q = grid_ra.shape[0]
    init = jnp.zeros((nbins, q, q), jnp.float32)
    return _scan_packs(
        body, init, pixels, wcs, ints, floats, psf_kernels, gate, pack_idx
    )[0]


def _scan_clip(
    pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
    center, thresh, use_kernel, block_rows, interpret, pack_idx=None,
):
    """Robust final pass: accumulate only samples inside the clip window."""

    def body(carry, px, wv, ints_p, floats_p, kern_p, gate_p):
        coadd, depth = carry
        accept = _accept_from_meta(ints_p, floats_p, qvec) & gate_p
        if use_kernel:
            c, d = warp_ops.coadd_clip(
                px, wv, accept.astype(jnp.float32), grid_ra, grid_dec,
                center, thresh, psf_kernels=kern_p,
                block_rows=block_rows, interpret=interpret,
            )
        else:
            tiles, covs = mapper.map_batch(
                px, wv, accept, grid_ra, grid_dec, psf_kernels=kern_p
            )
            c, d = reducer.clip_local(tiles, covs, center, thresh)
        return (coadd + c, depth + d), None

    q = grid_ra.shape[0]
    z = jnp.zeros((q, q), jnp.float32)
    return _scan_packs(
        body, (z, z), pixels, wcs, ints, floats, psf_kernels, gate, pack_idx
    )[0]


def _robust_passes(
    pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
    clip_k, use_kernel, block_rows, interpret, reduce, median_bins,
    pack_idx=None,
):
    """All robust passes composed in one traceable program (the eager path).

    Identical operand math to the streaming multi-pass contract — fusing
    only removes the host round-trips between passes, so the eager and
    streaming results agree to float tolerance (XLA may fuse the in-program
    center/threshold arithmetic differently from the between-pass jits).

    XLA path: the multi-pass schedule re-warps every sample per pass —
    mandatory for streaming windows, where the warped stack must never be
    resident, but a 2-3x warp tax when the layout already is.  So the eager
    XLA program warps each gated pack ONCE (the pack scan emits the warped
    (tiles, covs) as stacked scan outputs) and runs the whole estimator as
    `reducer.robust_local` over the stored stack: the clipped mean costs
    ~1 warp + cheap moments instead of 2 full warps.  The warped stack
    (n_packs*capacity, npix, npix) is resident for the dispatch — budget-
    bounded engines take the streaming multi-pass path instead, so this
    never competes with a device-memory budget.  The Pallas lane keeps the
    per-pass schedule: its fused warp+reduce kernels never materialize
    tiles, which is their point.
    """
    if not use_kernel:
        # Keep the warp body untouched (anything added to it — moment
        # partials in the carry or as extra scan outputs — measures
        # 20-30% slower end to end; XLA's scan codegen degrades once the
        # body grows reductions) and run the whole estimator over the
        # stored stack instead.
        def body(contrib, px, wv, ints_p, floats_p, kern_p, gate_p):
            accept = _accept_from_meta(ints_p, floats_p, qvec) & gate_p
            tiles, covs = mapper.map_batch(
                px, wv, accept, grid_ra, grid_dec, psf_kernels=kern_p
            )
            return contrib + accept.sum(), (tiles, covs)

        contrib, (tiles, covs) = _scan_packs(
            body, jnp.zeros((), jnp.int32), pixels, wcs, ints, floats,
            psf_kernels, gate, pack_idx,
        )
        q = grid_ra.shape[0]
        coadd, depth = reducer.robust_local(
            tiles.reshape(-1, q, q), covs.reshape(-1, q, q),
            reduce, clip_k, median_bins,
        )
        return coadd, depth, contrib, gate.sum()

    s0, s1, s2, contrib, considered = _scan_moments(
        pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
        use_kernel, block_rows, interpret, pack_idx=pack_idx,
    )
    mu, sigma = reducer.clip_stats(s0, s1, s2)
    if reduce == "median":
        lo, w, inv_w = reducer.hist_bounds(s0, s1, s2, median_bins)
        hist = _scan_hist(
            pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra,
            grid_dec, lo, inv_w, median_bins, use_kernel, block_rows,
            interpret, pack_idx=pack_idx,
        )
        center = reducer.hist_median(hist, s0, lo, w)
    else:
        center = mu
    thresh = reducer.clip_threshold(center, sigma, clip_k)
    coadd, depth = _scan_clip(
        pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
        center, thresh, use_kernel, block_rows, interpret, pack_idx=pack_idx,
    )
    return coadd, depth, contrib, considered


@partial(jax.jit, static_argnames=(
    "use_kernel", "block_rows", "interpret", "reduce", "median_bins"))
def _robust_scan(
    pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
    clip_k, use_kernel=False, block_rows=8, interpret=True,
    reduce="clipped", median_bins=16, pack_idx=None,
):
    """One robust plan against a resident layout — still ONE dispatch."""
    return _robust_passes(
        pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
        clip_k, use_kernel, block_rows, interpret, reduce, median_bins,
        pack_idx=pack_idx,
    )


@partial(jax.jit, static_argnames=(
    "use_kernel", "block_rows", "interpret", "reduce", "median_bins"))
def _robust_scan_batch(
    pixels, wcs, ints, floats, psf_kernels, gates, qvecs, grids_ra, grids_dec,
    clip_k, use_kernel=False, block_rows=8, interpret=True,
    reduce="clipped", median_bins=16, pack_idx=None,
):
    """K stacked robust plans, ONE dispatch (shared sparse index, like
    `_coadd_scan_batch_sparse`)."""

    def one(gate, qvec, grid_ra, grid_dec):
        return _robust_passes(
            pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra,
            grid_dec, clip_k, use_kernel, block_rows, interpret, reduce,
            median_bins, pack_idx=pack_idx,
        )

    return jax.vmap(one)(gates, qvecs, grids_ra, grids_dec)


# Streaming per-pass entry points: one jitted dispatch per (window, pass),
# returning additive partial tuples the WindowTracker can journal/resume.
@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _moments_scan_sparse(
    pixels, wcs, ints, floats, psf_kernels, pack_idx, gate, qvec,
    grid_ra, grid_dec, use_kernel=False, block_rows=8, interpret=True,
):
    return _scan_moments(
        pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
        use_kernel, block_rows, interpret, pack_idx=pack_idx,
    )


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret",
                                   "nbins"))
def _hist_scan_sparse(
    pixels, wcs, ints, floats, psf_kernels, pack_idx, gate, qvec,
    grid_ra, grid_dec, lo, inv_w, nbins=16, use_kernel=False, block_rows=8,
    interpret=True,
):
    return (_scan_hist(
        pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
        lo, inv_w, nbins, use_kernel, block_rows, interpret,
        pack_idx=pack_idx,
    ),)


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _clip_scan_sparse(
    pixels, wcs, ints, floats, psf_kernels, pack_idx, gate, qvec,
    grid_ra, grid_dec, center, thresh, use_kernel=False, block_rows=8,
    interpret=True,
):
    return _scan_clip(
        pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra, grid_dec,
        center, thresh, use_kernel, block_rows, interpret, pack_idx=pack_idx,
    )


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _moments_scan_batch_sparse(
    pixels, wcs, ints, floats, psf_kernels, pack_idx, gates, qvecs,
    grids_ra, grids_dec, use_kernel=False, block_rows=8, interpret=True,
):
    def one(gate, qvec, grid_ra, grid_dec):
        return _scan_moments(
            pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra,
            grid_dec, use_kernel, block_rows, interpret, pack_idx=pack_idx,
        )

    return jax.vmap(one)(gates, qvecs, grids_ra, grids_dec)


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret",
                                   "nbins"))
def _hist_scan_batch_sparse(
    pixels, wcs, ints, floats, psf_kernels, pack_idx, gates, qvecs,
    grids_ra, grids_dec, los, inv_ws, nbins=16, use_kernel=False,
    block_rows=8, interpret=True,
):
    def one(gate, qvec, grid_ra, grid_dec, lo, inv_w):
        return (_scan_hist(
            pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra,
            grid_dec, lo, inv_w, nbins, use_kernel, block_rows, interpret,
            pack_idx=pack_idx,
        ),)

    return jax.vmap(one)(gates, qvecs, grids_ra, grids_dec, los, inv_ws)


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _clip_scan_batch_sparse(
    pixels, wcs, ints, floats, psf_kernels, pack_idx, gates, qvecs,
    grids_ra, grids_dec, centers, threshs, use_kernel=False, block_rows=8,
    interpret=True,
):
    def one(gate, qvec, grid_ra, grid_dec, center, thresh):
        return _scan_clip(
            pixels, wcs, ints, floats, psf_kernels, gate, qvec, grid_ra,
            grid_dec, center, thresh, use_kernel, block_rows, interpret,
            pack_idx=pack_idx,
        )

    return jax.vmap(one)(gates, qvecs, grids_ra, grids_dec, centers, threshs)


# Between-pass operand computation, jitted so the streaming passes share one
# compiled formula (the center/threshold math never runs on the host).
@jax.jit
def _clip_operands(s0, s1, s2, clip_k):
    mu, sigma = reducer.clip_stats(s0, s1, s2)
    return mu, reducer.clip_threshold(mu, sigma, clip_k)


@partial(jax.jit, static_argnames=("nbins",))
def _hist_operands(s0, s1, s2, nbins=16):
    return reducer.hist_bounds(s0, s1, s2, nbins)


@jax.jit
def _median_operands(hist, s0, s1, s2, lo, w, clip_k):
    _, sigma = reducer.clip_stats(s0, s1, s2)
    center = reducer.hist_median(hist, s0, lo, w)
    return center, reducer.clip_threshold(center, sigma, clip_k)


@jax.jit
def _match_packs(pixels, kernels):
    """Query-independent PSF matching of resident packs, on device.

    (P, cap, H, W) pixels x (P, cap, ...) kernel bank -> matched pixels of
    the same shape.  `lax.map` steps the pack axis so each step convolves
    one (cap, H, W) pack — the *same* inner program `mapper.map_batch` runs
    when the bank is threaded into a dispatch, which is what makes cached
    and uncached matched pixels bitwise-identical (parity-tested).  No host
    bytes move: both operands are already resident.
    """
    return jax.lax.map(
        lambda xs: psf.convolve_batch(xs[0], xs[1]), (pixels, kernels)
    )


@partial(jax.jit, static_argnames=("npix", "use_kernel", "interpret"))
def _mosaic_bricks(tiles, covs, offsets, npix, use_kernel=False,
                   interpret=True):
    """Merge cached brick tiles into one (npix, npix) mosaic (DESIGN.md §9).

    One jitted dispatch over (B, b, b) device-resident brick coadds +
    weight maps and their (B, 2) output offsets.  The XLA scan and the
    Pallas kernel accumulate into a zero canvas in the same brick order,
    so both match the fresh lattice-window scan bitwise.
    """
    if use_kernel:
        return warp_ops.mosaic_bricks(
            tiles, covs, offsets, npix, interpret=interpret
        )
    return reducer.mosaic_tiles(tiles, covs, offsets, npix)


def _sync(x):
    """The streaming executors' ONE host sync, at reduce time (DESIGN.md §6).

    Every window dispatch and every chunk upload before this point is
    asynchronous — the device scans window N while the host enqueues the
    N+1 upload — so a streaming query's wall clock is max(upload, compute)
    per window, not their sum.  Tests monkeypatch this to pin the
    block-only-at-reduce-time contract.
    """
    return jax.block_until_ready(x)


class CoaddEngine:
    """Plans queries on the host, executes them against resident layouts.

    Pixels cross host->device exactly once per layout (`device_dataset`) and
    host->mesh exactly once per (layout, mesh) (`mesh_dataset`); every query
    — single, batched, or distributed — is a single jitted dispatch.  Set
    ``use_kernel=True`` to fuse map+reduce through the Pallas ``coadd_fused``
    kernel (``kernel_interpret=False`` on real TPUs lowers through Mosaic),
    and ``match_psf_sigma`` to convolve every image to a common PSF width in
    the map stage before warping.
    """

    def __init__(
        self,
        survey: Survey,
        pack_capacity: int = 64,
        use_kernel: bool = False,
        block_rows: Optional[int] = None,
        kernel_interpret: bool = True,
        match_psf_sigma: Optional[float] = None,
        measured_psf: Optional[bool] = None,
        matched_pixel_cache: bool = True,
        sparse: bool = True,
        device_budget_bytes: Optional[int] = None,
        stream_chunk_packs: Optional[int] = None,
        on_fault: str = "retry",
        fault_max_attempts: int = 3,
        fault_backoff_s: float = 0.05,
        straggler_factor: Optional[float] = None,
        verify_digests: bool = False,
        fault_injector: Optional[ChaosInjector] = None,
        brick_deg: float = 0.25,
        brick_npix: int = 64,
        journal_dir: Optional[str] = None,
        journal_max_age_s: float = 7 * 86400.0,
        clip_k: float = 3.0,
        median_bins: int = 16,
    ):
        self.survey = survey
        # Robust-reduction knobs (DESIGN.md §11): the sigma-clip radius and
        # the binapprox histogram resolution shared by every executor.  Part
        # of `result_key` for robust plans — two engines with different knobs
        # must never share cached bytes.
        self.clip_k = float(clip_k)
        self.median_bins = int(median_bins)
        self.use_kernel = use_kernel
        self.block_rows = block_rows  # None -> autotune per (npix, H, W)
        self.kernel_interpret = kernel_interpret
        self.match_psf_sigma = match_psf_sigma
        # Measured-PSF homogenization (DESIGN.md §7): None = auto (use the
        # survey's empirical stamps when present, separable Gaussian bank
        # otherwise); True forces stamps (loud error if absent); False
        # forces the Gaussian fallback — the parity-test baseline.
        self.measured_psf = measured_psf
        # Matched-pixel residency cache (§7): on the XLA map path the
        # matching convolution is query-independent, so convolve ONCE per
        # (layout, target) at residency time and cache the matched pixels
        # under the device budget, instead of re-convolving inside every
        # dispatch.  The Pallas path keeps its in-kernel recompute (the
        # documented fusion tradeoff), so this flag is inert there.
        self.matched_pixel_cache = matched_pixel_cache
        # Sparse execution (DESIGN.md §5): gather only the packs a gate
        # opens before scanning, and reblock degenerate layouts at residency
        # time.  False reproduces the dense masked-discard scan over every
        # pack — kept as the parity/benchmark baseline.
        self.sparse = sparse
        # Streaming residency (DESIGN.md §6): with a device budget set,
        # layouts stop uploading eagerly; queries scan budget-sized chunk
        # windows with uploads double-buffered behind compute, and the
        # ResidencyManager LRU-evicts cold chunks — archives larger than
        # device memory run correctly, just with more windows.
        self.device_budget_bytes = device_budget_bytes
        self.stream_chunk_packs = stream_chunk_packs  # None -> budget/2 sizing
        # Fault policy (DESIGN.md §8): how the streaming executors respond
        # to upload failures, poisoned chunks, and stragglers.
        #   "retry"      — WindowTracker re-executes transient failures with
        #                  capped exponential backoff (the default);
        #   "quarantine" — like retry, but persistent poison gates the bad
        #                  packs out and the query completes partial=True;
        #   "raise"      — no tracker at all: any fault aborts the query
        #                  (the zero-overhead baseline BENCH compares against).
        if on_fault not in ("retry", "quarantine", "raise"):
            raise ValueError(
                f"on_fault must be 'retry', 'quarantine', or 'raise'; "
                f"got {on_fault!r}"
            )
        self.on_fault = on_fault
        self.fault_max_attempts = fault_max_attempts
        self.fault_backoff_s = fault_backoff_s
        # Speculative re-execution of straggler windows (off by default):
        # timing a window means blocking on it, so enabling this trades the
        # one-sync-at-reduce-time contract for straggler detection — the
        # documented speculation cost (§8).
        self.straggler_factor = straggler_factor
        # Chunk verification scope: the NaN/Inf scan always runs on tracked
        # builds; digest comparison against the host seqfile (catches finite
        # corruption) is opt-in because it costs a sha256 per pack per build.
        self.verify_digests = verify_digests
        self.fault_injector = fault_injector
        # Window-partial journals of killed queries, keyed by job key and
        # capped: a re-issued query replays only its missing windows.
        self._journals: "OrderedDict[str, Dict]" = OrderedDict()
        self._journal_cap = 16
        # Durable fault domain (DESIGN.md §8): with ``journal_dir`` set,
        # window journals write through to crash-safe on-disk segments
        # (`durable.JournalStore`) and the BrickStore host tier persists
        # (`durable.BrickSpill`) — a SIGKILLed query or materialization
        # resumes bitwise in a *fresh process*.  Journals of completed jobs
        # are removed atomically; orphans older than ``journal_max_age_s``
        # are swept here at init.
        self.journal_dir = journal_dir
        self.journal_store: Optional[JournalStore] = None
        brick_spill = None
        if journal_dir is not None:
            self.journal_store = JournalStore(
                os.path.join(journal_dir, "windows"),
                max_age_s=journal_max_age_s,
            )
            brick_spill = BrickSpill(os.path.join(journal_dir, "bricks"))
        # Quarantine releases since the last streaming result, reported as
        # JobStats.requarantine_released by the next query (additive).
        self._requarantine_pending = 0
        self.residency = ResidencyManager(device_budget_bytes)
        if fault_injector is not None:
            self.residency.fault_hook = fault_injector.on_upload
        self.camcol_dec = camcol_dec_table(survey)
        self.sql = SpatialIndex.build(survey)
        self._datasets: Dict[str, PackedDataset] = {}
        self._exec_cache: Dict[str, Tuple[PackedDataset, Optional[SlotRemap]]] = {}
        self._device_cache: Dict[str, DevicePackedDataset] = {}
        self._mesh_cache: Dict[Tuple, MeshResidentDataset] = {}
        self._psf_banks: Dict[Tuple, np.ndarray] = {}
        self._psf_device: Dict[Tuple, "jax.Array"] = {}
        self._pack_capacity = pack_capacity
        self.pack_upload_count = 0   # host->device uploads of pack pixels
        self.mesh_upload_count = 0   # host->mesh uploads of whole layouts
        self.dispatch_count = 0      # jitted device dispatches issued
        self.matched_builds = 0      # device-side matched-pixel constructions
        # Brick tessellation (DESIGN.md §9): the materialized-coadd tier.
        # The grid is built lazily from the survey footprint; the store
        # shares the engine's ResidencyManager so brick tiles compete with
        # streaming chunks under one device budget (at COST_BRICK priority).
        self.brick_deg = brick_deg
        self.brick_npix = brick_npix
        self._brick_grid: Optional[BrickGrid] = None
        self.brick_store = BrickStore(self.residency, spill=brick_spill)

    # ----- dataset layouts (built lazily, cached) -----
    def dataset(self, layout: str) -> PackedDataset:
        if layout not in self._datasets:
            if layout == "per_file":
                self._datasets[layout] = pack_per_file(self.survey)
            elif layout == "unstructured":
                self._datasets[layout] = pack_unstructured(
                    self.survey, self._pack_capacity
                )
            elif layout == "structured":
                self._datasets[layout] = pack_structured(
                    self.survey, self._pack_capacity
                )
            else:
                raise ValueError(layout)
        return self._datasets[layout]

    def exec_dataset(self, layout: str) -> Tuple[PackedDataset, Optional[SlotRemap]]:
        """Execution-side form of a layout + the gate remap onto it.

        Planning always sees the layout as the method defines it (per-file
        gating stays per-file); execution may re-pack it for scan efficiency.
        The per-file layout's (P=N, cap=1) geometry makes every scan step a
        one-image pack — pure scan overhead — so under sparse execution it is
        reblocked into dense ``pack_capacity``-slot super-packs at residency
        time, and plan gates are rewritten through the returned `SlotRemap`.
        """
        if layout not in self._exec_cache:
            ds = self.dataset(layout)
            if self.sparse and layout == "per_file" and ds.capacity < self._pack_capacity:
                self._exec_cache[layout] = ds.reblock(self._pack_capacity)
            else:
                self._exec_cache[layout] = (ds, None)
        return self._exec_cache[layout]

    def device_dataset(self, layout: str) -> DevicePackedDataset:
        """Device-resident form of a layout; uploaded once, then cached."""
        if layout not in self._device_cache:
            exec_ds, _ = self.exec_dataset(layout)
            self._device_cache[layout] = exec_ds.to_device()
            self.pack_upload_count += 1
        return self._device_cache[layout]

    def mesh_dataset(
        self, layout: str, mesh: Mesh, shard_axes: Tuple[str, ...]
    ) -> MeshResidentDataset:
        """Mesh-resident form of a layout; sharded once per (layout, mesh).

        A cache hit means a distributed job moves zero pixel bytes: its only
        host->mesh traffic is slot gates + query vectors + output grids.
        The key carries the PSF state because the sharded dataset bakes in
        its kernel bank — a retuned engine must re-shard, not silently
        serve the old configuration's kernels.
        """
        key = (layout, mesh, tuple(shard_axes), self._psf_state())
        if key not in self._mesh_cache:
            # Retune hygiene: one sharded copy per (layout, mesh, axes) —
            # drop the old target's rather than pinning every historical one.
            for k in [k for k in self._mesh_cache if k[:3] == key[:3]]:
                del self._mesh_cache[k]
            exec_ds, _ = self.exec_dataset(layout)
            self._mesh_cache[key] = exec_ds.to_mesh(
                mesh, tuple(shard_axes), psf_kernels=self.psf_kernel_bank(layout)
            )
            self.mesh_upload_count += 1
        return self._mesh_cache[key]

    # ----- PSF matching (kernel banks precomputed on host, cached) -----
    def _psf_state(self) -> Optional[Tuple]:
        """Hashable id of the PSF configuration every kernel bank, matched-
        pixel entry, chunk and mesh dataset derives from — (target,
        measured-mode), or None when matching is off.  Every such cache
        keys on this, so retuning either knob (the supported live-mutation
        flow) misses instead of silently serving stale kernels."""
        if self.match_psf_sigma is None:
            return None
        return (float(self.match_psf_sigma), self.measured_psf)

    def psf_kernel_bank(self, layout: str) -> Optional[np.ndarray]:
        """Per-slot matching kernels, or None when matching is disabled.

        (P, cap, K, K) measured-PSF homogenization kernels when the layout
        carries empirical stamps (`psf.homogenization_bank` — Fourier least
        squares to the Gaussian target), the separable (P, cap, K) Gaussian
        bank otherwise; ``measured_psf`` forces either side.  Built against
        the *execution* form so the bank lines up slot-for-slot with the
        resident (possibly reblocked) arrays.
        """
        if self.match_psf_sigma is None:
            return None
        # Keyed per (layout, psf-state), like the matched-pixel entries: an
        # engine retuned to a new target or measured-mode must never reuse
        # stale kernels.
        key = (layout, self._psf_state())
        if key not in self._psf_banks:
            # Retune hygiene: keep one host bank per layout.
            for k in [k for k in self._psf_banks if k[0] == layout]:
                del self._psf_banks[k]
            exec_ds, _ = self.exec_dataset(layout)
            measured = (
                self.measured_psf if self.measured_psf is not None
                else exec_ds.psf_stamps is not None
            )
            if measured:
                if exec_ds.psf_stamps is None:
                    raise ValueError(
                        "measured_psf=True but the survey carries no PSF "
                        "stamps (SurveyConfig.psf_stamps)"
                    )
                self._psf_banks[key] = psf.homogenization_bank(
                    exec_ds.psf_stamps,
                    exec_ds.floats["psf_sigma"],
                    self.match_psf_sigma,
                )
            else:
                self._psf_banks[key] = psf.matching_kernel_bank(
                    exec_ds.floats["psf_sigma"], self.match_psf_sigma
                )
        return self._psf_banks[key]

    def _device_psf_kernels(self, layout: str):
        bank = self.psf_kernel_bank(layout)
        if bank is None:
            return None
        key = (layout, self._psf_state())
        if key not in self._psf_device:
            # Retune hygiene: one device bank per layout — drop the old
            # target's copy rather than pinning every historical one.
            for k in [k for k in self._psf_device if k[0] == layout]:
                del self._psf_device[k]
            self._psf_device[key] = jnp.asarray(bank)
        return self._psf_device[key]

    # ----- matched-pixel residency cache (DESIGN.md §7) -----
    def _matched_mode(self) -> bool:
        """Whether dispatches read cached matched pixels instead of a bank.

        Only the XLA map path qualifies: the Pallas kernel fuses the
        convolution into the warp on purpose (recompute-for-fusion), so
        caching would buy it nothing but HBM.
        """
        return (
            self.match_psf_sigma is not None
            and not self.use_kernel
            and self.matched_pixel_cache
        )

    def _matched_device_dataset(
        self, layout: str, dev: DevicePackedDataset
    ) -> Tuple[DevicePackedDataset, int]:
        """The eager layout with pixels replaced by PSF-matched pixels.

        A *derived* residency entry keyed (layout, target): built once per
        engine by convolving the resident pixels with the device bank —
        on-device compute, zero H2D — and served from the LRU afterwards.
        Metadata/wcs alias the raw resident arrays, so the cache charges
        only the matched pixel bytes.  Returns (dataset, hits) where hits
        is 1 when the entry was already resident.
        """
        key = ("matched", layout, self._psf_state())
        # Retune hygiene: the eager manager never evicts (budget None), so
        # shed the previous target's whole-layout matched copy explicitly —
        # retunes must not pin one full pixel array per historical target.
        self.residency.drop_matching(
            lambda k: k[:2] == ("matched", layout) and k != key
        )
        hits0 = self.residency.hits

        def build():
            kern = self._device_psf_kernels(layout)
            self.matched_builds += 1
            return DevicePackedDataset(
                pixels=_match_packs(dev.pixels, kern),
                wcs=dev.wcs,
                ints=dev.ints,
                floats=dev.floats,
            )

        payload = self.residency.acquire(
            key, int(dev.pixels.nbytes), build, h2d=False,
            cost=COST_MATCHED_CHUNK,
        )
        return payload, self.residency.hits - hits0

    # ----- streaming residency (DESIGN.md §6) -----
    def _bank_pack_nbytes(self, layout: str) -> int:
        """Resident bytes ONE pack's PSF matching-kernel bank adds (0 when
        matching is off) — charged alongside pixel bytes so the budget
        bounds everything a chunk keeps on device."""
        bank = self.psf_kernel_bank(layout)
        return 0 if bank is None else bank[0].nbytes

    def _chunk_packs(self, exec_ds: PackedDataset) -> int:
        """Packs per residency chunk: half the budget, so two chunks —
        the one being scanned and the one uploading behind it — fit
        resident simultaneously (double buffering)."""
        if self.stream_chunk_packs is not None:
            return max(1, min(self.stream_chunk_packs, exec_ds.n_packs))
        pack_bytes = max(
            exec_ds.pack_nbytes() + self._bank_pack_nbytes(exec_ds.layout), 1
        )
        fit = int(self.device_budget_bytes // (2 * pack_bytes))
        return max(1, min(fit, exec_ds.n_packs))

    @property
    def _fault_tolerant(self) -> bool:
        """Whether streaming queries run through the WindowTracker (§8)."""
        return self.on_fault != "raise"

    @property
    def _verify_chunks(self) -> bool:
        """Whether chunk builds stage-and-verify host pixels before upload.

        On whenever faults are handled *or* injected: with ``on_fault=
        "raise"`` plus an injector, poison is still detected — it just
        aborts the query (the loud baseline) instead of healing.
        """
        return self._fault_tolerant or self.fault_injector is not None

    def _staged_chunk_pixels(
        self, exec_ds: PackedDataset, start: int, stop: int,
        drop: FrozenSet[int],
    ) -> Optional[np.ndarray]:
        """Stage, verify, and sanitize a chunk's host pixels (DESIGN.md §8).

        Returns the pixel array `to_device_chunk` should upload, or None to
        upload the seqfile slice directly (verification off).  Injection
        corrupts a *copy*; detection (NaN/Inf scan, plus digest comparison
        against the host seqfile under ``verify_digests``) raises
        `PoisonedChunkError` with the offending global pack indices; packs
        in ``drop`` (already quarantined) are zeroed instead — pixel zeros,
        not just gate falses, because a NaN surviving into the masked scan
        would still poison the accumulator (NaN * 0 == NaN).
        """
        if not self._verify_chunks:
            return None
        px = exec_ds.pixels[start:stop]
        if self.fault_injector is not None:
            px = self.fault_injector.corrupt_chunk(start, stop, px)
        drop_local = sorted(p - start for p in drop if start <= p < stop)
        bad = exec_ds.verify_chunk(
            start, stop, px,
            skip=frozenset(p + start for p in drop_local),
            check_digests=self.verify_digests,
        )
        if bad:
            raise PoisonedChunkError(bad)
        if drop_local:
            if not px.flags.owndata:  # still a seqfile view: copy before zeroing
                px = np.array(px, copy=True)
            px[drop_local] = 0.0
        return px

    def _resident_chunk(self, layout: str, exec_ds: PackedDataset,
                        start: int, stop: int,
                        drop: FrozenSet[int] = frozenset()):
        """(DevicePackedDataset, psf chunk) for packs [start, stop), via LRU.

        In matched mode (§7) the chunk *is* the matched-pixel cache: the
        raw pixels upload once, the query-independent matching convolution
        runs on device right behind the transfer, and only the matched
        chunk stays resident — repeat queries hit the LRU and pay neither
        the upload nor the convolution.  The key carries the PSF target so
        engines retuned to a different target never alias.

        ``drop`` lists quarantined global packs (§8): their rows upload as
        zeros and the key carries them, so a sanitized chunk never aliases
        the clean one.
        """
        matched = self._matched_mode()
        # The payload embeds PSF state either way (matched pixels, or the
        # bank slice riding alongside), so the key always carries the
        # psf-state: a retuned engine must miss, not reuse stale kernels.
        state = self._psf_state()
        key = (
            (layout, start, stop, "matched", state)
            if matched else (layout, start, stop, state)
        )
        drop_here = tuple(sorted(p for p in drop if start <= p < stop))
        if drop_here:
            key = key + ("quarantine", drop_here)

        def build():
            staged = self._staged_chunk_pixels(exec_ds, start, stop, drop)
            dev = exec_ds.to_device_chunk(start, stop, pixels=staged)
            bank = self.psf_kernel_bank(layout)
            self.pack_upload_count += 1
            if matched:
                self.matched_builds += 1
                dev = DevicePackedDataset(
                    pixels=_match_packs(
                        dev.pixels, jnp.asarray(bank[start:stop])
                    ),
                    wcs=dev.wcs,
                    ints=dev.ints,
                    floats=dev.floats,
                )
                return (dev, None)
            kern = None if bank is None else jax.device_put(bank[start:stop])
            return (dev, kern)

        nbytes = exec_ds.chunk_nbytes(start, stop) + (
            0 if matched
            else (stop - start) * self._bank_pack_nbytes(layout)
        )
        # A matched build transiently holds the raw pixel chunk AND the
        # bank slice alive next to its matched copy until the convolution
        # retires — declare both so peak_bytes reports the true build-time
        # footprint.  (The unmatched branch's bank slice stays resident and
        # is already counted inside ``nbytes``.)
        transient = (
            (int(exec_ds.pixels[0].nbytes) + self._bank_pack_nbytes(layout))
            * (stop - start)
            if matched else 0
        )
        return self.residency.acquire(
            key, nbytes, build, transient_bytes=transient,
            cost=COST_MATCHED_CHUNK if matched else COST_RAW_CHUNK,
        )

    # ----- shared helpers -----
    def _grids(self, query: CoaddQuery):
        gr, gd = mapper.query_grid_sky(query)
        return jnp.asarray(gr), jnp.asarray(gd)

    def _plan_grids(self, plan: CoaddPlan):
        """The plan's output grid: its `grid_sky` override (brick-lattice
        plans, §9) when present, the query's own TAN grid otherwise."""
        if plan.grid_sky is not None:
            gr, gd = plan.grid_sky
            return jnp.asarray(gr), jnp.asarray(gd)
        return self._grids(plan.query)

    @staticmethod
    def _grid_tag(plan: CoaddPlan) -> str:
        """Journal-identity tag of a plan's grid override (empty = default).

        `_job_key` must distinguish a lattice-window scan from the plain
        query-grid scan of the same bounds: their window partials differ
        bitwise, so replaying one journal into the other would be wrong.
        """
        return grid_digest(plan.grid_sky)

    def _block_rows(self, query: CoaddQuery, ds: PackedDataset) -> int:
        if self.block_rows is not None:
            return self.block_rows
        h, w = ds.image_hw()
        bank = self.psf_kernel_bank(ds.layout) if self.use_kernel else None
        return warp_ops.autotune_block_rows(
            query.npix, h, w,
            psf_kernel_width=0 if bank is None else bank.shape[-1],
            psf_kernel_2d=bank is not None and bank.ndim == 4,
        )

    # ----- planning: the six methods differ ONLY in gate construction -----
    def plan(self, query: CoaddQuery, method: str,
             reduce: str = "mean") -> CoaddPlan:
        if method not in METHODS:
            raise ValueError(f"unknown method {method}; expected one of {METHODS}")
        if reduce not in reducer.REDUCERS:
            raise ValueError(
                f"unknown reduce {reduce!r}; expected one of {reducer.REDUCERS}"
            )
        plan = getattr(self, f"plan_{method}")(query)
        # The reduction variant is plan state (it changes the result bytes):
        # set after the method planner so all six stay reduce-agnostic.
        plan.reduce = reduce
        return plan

    def plan_raw_fits(self, query: CoaddQuery) -> CoaddPlan:
        ds = self.dataset("per_file")
        t0 = time.perf_counter()
        # No prefilter: every file is "located" and becomes a mapper input.
        gate = ds.valid.copy()
        t_locate = time.perf_counter() - t0
        return CoaddPlan("raw_fits", "per_file", gate, _query_vec(query),
                         query, t_locate, psf_target=self.match_psf_sigma)

    def plan_raw_fits_prefiltered(self, query: CoaddQuery) -> CoaddPlan:
        ds = self.dataset("per_file")
        t0 = time.perf_counter()
        mask = glob_file_mask(self.survey.meta_table(), query, self.camcol_dec)
        gate = ds.valid & mask[:, None]  # per-file layout: pack == file
        t_locate = time.perf_counter() - t0
        return CoaddPlan("raw_fits_prefiltered", "per_file", gate,
                         _query_vec(query), query, t_locate,
                         psf_target=self.match_psf_sigma)

    def plan_unstructured_seq(self, query: CoaddQuery) -> CoaddPlan:
        ds = self.dataset("unstructured")
        t0 = time.perf_counter()
        gate = ds.valid.copy()  # unprunable by construction: read every pack
        t_locate = time.perf_counter() - t0
        return CoaddPlan("unstructured_seq", "unstructured", gate,
                         _query_vec(query), query, t_locate,
                         psf_target=self.match_psf_sigma)

    def plan_structured_seq_prefiltered(self, query: CoaddQuery) -> CoaddPlan:
        ds = self.dataset("structured")
        t0 = time.perf_counter()
        mask = glob_pack_mask(ds, query, self.camcol_dec)
        gate = ds.valid & mask[:, None]
        t_locate = time.perf_counter() - t0
        return CoaddPlan("structured_seq_prefiltered", "structured", gate,
                         _query_vec(query), query, t_locate,
                         psf_target=self.match_psf_sigma)

    def _plan_sql(self, layout: str, query: CoaddQuery, method: str) -> CoaddPlan:
        ds = self.dataset(layout)
        t0 = time.perf_counter()
        ids = self.sql.select(query)
        # The index maps ids -> (pack, slot); the "gather" is a metadata-only
        # slot gate over the resident containers, so exact selection costs no
        # pixel movement at all.
        gate = ds.slot_mask(ids)
        t_locate = time.perf_counter() - t0
        return CoaddPlan(method, layout, gate, _query_vec(query), query,
                         t_locate, psf_target=self.match_psf_sigma)

    def plan_sql_unstructured(self, query: CoaddQuery) -> CoaddPlan:
        return self._plan_sql("unstructured", query, "sql_unstructured")

    def plan_sql_structured(self, query: CoaddQuery) -> CoaddPlan:
        return self._plan_sql("structured", query, "sql_structured")

    def _exec_gate(self, plan: CoaddPlan) -> np.ndarray:
        """A plan's gate in execution-layout coordinates (remapped if reblocked)."""
        _, remap = self.exec_dataset(plan.layout)
        return remap.apply(plan.gate) if remap is not None else plan.gate

    def _sparse_index(self, gate_or_gates: np.ndarray) -> Optional[SparseScanIndex]:
        """The gather plan for a gate (or gate stack), or None for dense.

        Sparse execution only pays when the budget bucket is smaller than
        the layout — a full-archive gate (raw_fits, unstructured_seq)
        degrades gracefully to the dense scan of the same program shape.
        """
        if not self.sparse:
            return None
        sp = (
            union_sparse_index(gate_or_gates)
            if gate_or_gates.ndim == 3
            else sparse_pack_index(gate_or_gates)
        )
        return sp if sp.worthwhile else None

    def _stream_windows(self, exec_ds: PackedDataset,
                        gate_any: np.ndarray) -> List[ScanWindow]:
        """Chunk-aligned window schedule for a (P,)-any gate (or all packs
        when sparse execution is off — dense semantics scan everything)."""
        if self.sparse:
            gated = np.nonzero(gate_any)[0]
        else:
            gated = np.arange(exec_ds.n_packs)
        return window_schedule(gated, exec_ds.n_packs,
                               self._chunk_packs(exec_ds))

    def _job_key(self, method: str, layout: str, gates: np.ndarray,
                 qvecs: np.ndarray, npix: int,
                 windows: List[ScanWindow], grid_tag: str = "") -> str:
        """Cross-query identity of a streaming job's window journal (§8).

        A digest over everything that determines a window partial's value —
        method/layout/PSF state, the gate and query-vector bytes, the output
        grid size, the window partition itself, and the persistent
        quarantine set (a pack released between kill and resume changes the
        partials bitwise, so the resumed job must miss, not replay) — so a
        resumed query replays journaled partials only when they are
        bitwise-valid for it.
        """
        quar = tuple(sorted(self.residency.quarantined_packs(layout)))
        h = hashlib.sha256()
        h.update(
            f"{method}|{layout}|{npix}|{self._psf_state()}|{grid_tag}"
            f"|q{quar}".encode()
        )
        h.update(np.ascontiguousarray(gates).tobytes())
        h.update(np.ascontiguousarray(qvecs, np.float32).tobytes())
        for w in windows:
            h.update(
                np.array([w.start, w.stop, w.n_gated, w.budget], np.int64)
                .tobytes()
            )
        return h.hexdigest()

    def _journal_for(self, job_key: str) -> Dict:
        """The (possibly resumed) window journal for a job, LRU-capped.

        In-memory dict by default; with ``journal_dir`` a `DiskJournal`
        that replays any valid on-disk prefix at open — the resume path for
        a *fresh process* (the cap then only bounds open handles; disk
        state is untouched until completion removes it).
        """
        journal = self._journals.get(job_key)
        if journal is None:
            if self.journal_store is not None:
                journal = self.journal_store.open(job_key)
            else:
                journal = {}
            self._journals[job_key] = journal
            while len(self._journals) > self._journal_cap:
                _, old = self._journals.popitem(last=False)
                if hasattr(old, "close"):
                    old.close()
        else:
            self._journals.move_to_end(job_key)
        return journal

    def reverify_quarantined(self, layout: Optional[str] = None) -> List[int]:
        """Re-verify quarantined packs against the host seqfile (§8).

        Quarantine auto-release: for every registered layout (or just
        ``layout``), re-hash the quarantined packs' *current* host pixels;
        packs that verify — repaired in place, or never host-corrupt at all
        — leave the registry and regain gate coverage on the next query.
        Returns the released global pack indices; the count also surfaces as
        ``JobStats.requarantine_released`` on the next streaming result.
        """
        layouts = (
            [layout] if layout is not None
            else list(self.residency.quarantined)
        )
        released: List[int] = []
        for lay in layouts:
            exec_ds, _ = self.exec_dataset(lay)
            released.extend(self.residency.reverify_quarantined(lay, exec_ds))
        self._requarantine_pending += len(released)
        return released

    def _take_requarantine_released(self) -> int:
        n, self._requarantine_pending = self._requarantine_pending, 0
        return n

    def _empty_streaming_result(self, plan: CoaddPlan) -> CoaddResult:
        """The empty-selection answer under a device budget: exact zeros,
        zero windows, zero uploads.  Streaming's analogue of the §5
        empty-gate contract — and the guard that keeps the window-stat
        reductions (`max` over budgets) off an empty schedule entirely."""
        npix = plan.query.npix
        stats = JobStats(
            method=plan.method,
            files_considered=0,
            files_contributing=0,
            packs_touched=0,
            t_locate_s=plan.t_locate_s,
            t_map_reduce_s=0.0,
            t_total_s=plan.t_locate_s,
            dispatches=0,
            peak_resident_bytes=self._peak_resident_bytes(),
        )
        return CoaddResult(
            np.zeros((npix, npix), np.float32),
            np.zeros((npix, npix), np.float32),
            stats,
        )

    def _retire_journal(self, job_key: str) -> None:
        """Drop a completed job's window journal (memory + disk)."""
        old = self._journals.pop(job_key, None)
        if hasattr(old, "close"):
            old.close()
        if self.journal_store is not None:
            self.journal_store.remove(job_key)

    def _run_stream_windows(self, layout: str, exec_ds: PackedDataset,
                            windows: List[ScanWindow], dispatch,
                            job_key: str, keep_journal: bool = False):
        """Walk a window schedule: dispatch each window against its
        resident chunk, prefetch the next chunk (its async `device_put`
        rides behind the in-flight scan — the double buffer), accumulate
        the additive window partials on device, and host-sync ONCE at
        reduce time.  ``dispatch(dev, kern, win, dropped)`` returns the
        partial tuple.

        With ``on_fault="raise"`` this is the bare PR 4 loop (any failure
        aborts the query — the zero-overhead baseline).  Otherwise every
        window runs through a `WindowTracker` (§8): journaled under
        ``job_key`` (a killed query resumes replaying only missing
        windows), retried on transient faults, optionally speculated, and
        quarantine-completed on persistent poison.

        Returns (partials, (uploads, hits, evictions), elapsed_s,
        FaultCounters, quarantined-pack tuple).
        """
        up0, hit0, ev0 = (self.residency.uploads, self.residency.hits,
                          self.residency.evictions)
        t1 = time.perf_counter()
        if not self._fault_tolerant:
            cur = self._resident_chunk(layout, exec_ds,
                                       windows[0].start, windows[0].stop)
            acc = None
            for i, win in enumerate(windows):
                dev, kern = cur
                out = dispatch(dev, kern, win, frozenset())
                acc = out if acc is None else tuple(
                    a + b for a, b in zip(acc, out)
                )
                if i + 1 < len(windows):
                    nxt = windows[i + 1]
                    cur = self._resident_chunk(layout, exec_ds,
                                               nxt.start, nxt.stop)
            fc, quarantined = FaultCounters(), ()
        else:
            pre_quar = self.residency.quarantined_packs(layout)
            tracker = WindowTracker(
                policy=self.on_fault,
                max_attempts=self.fault_max_attempts,
                backoff_s=self.fault_backoff_s,
                straggler_factor=self.straggler_factor,
                injector=self.fault_injector,
                quarantined=pre_quar,
            )
            acquire = lambda win, drop: self._resident_chunk(  # noqa: E731
                layout, exec_ds, win.start, win.stop, drop=drop
            )
            disp = lambda ops, win, drop: dispatch(  # noqa: E731
                ops[0], ops[1], win, drop
            )
            journal = self._journal_for(job_key)
            try:
                acc, quarantined = tracker.run(
                    windows, acquire, disp, journal
                )
            except BaseException:
                # Durability point: fsync the disk journal so a fatal (an
                # injected kill, an OOM about to follow) leaves every
                # finished window committed for the resume.  Clean
                # completion skips the barrier — the journal is removed
                # two lines below, so syncing it first buys nothing.
                if hasattr(journal, "drain"):
                    journal.drain()
                raise
            finally:
                # Fresh quarantines persist even when the query dies: the
                # registry (released only by `reverify_quarantined`) is
                # what lets later queries skip the poison without re-paying
                # the retry storm.
                fresh = tracker.quarantined - set(pre_quar)
                if fresh:
                    self.residency.quarantine_packs(
                        layout, fresh,
                        getattr(exec_ds, "_pack_digest_cache", None),
                    )
            # Completed: the journal has served its purpose.  (A kill or a
            # fatal error raises out above this line, *keeping* the journal
            # — that asymmetry is the resume contract, in-memory and on
            # disk alike; only clean completion garbage-collects.)  Robust
            # multi-pass jobs (§11) pass ``keep_journal=True``: a pass's
            # journal must outlive its own completion so a kill *between*
            # passes still replays it — the orchestrator retires every pass
            # journal together once the final pass completes.
            if not keep_journal:
                self._retire_journal(job_key)
            fc, quarantined = tracker.counters, tuple(quarantined)
        _sync(acc[0])
        elapsed = time.perf_counter() - t1
        counters = (self.residency.uploads - up0,
                    self.residency.hits - hit0,
                    self.residency.evictions - ev0)
        return acc, counters, elapsed, fc, quarantined

    def _execute_streaming(self, plan: CoaddPlan) -> CoaddResult:
        """Windowed query under a device budget (DESIGN.md §6).

        The gated pack set is partitioned into residency-chunk windows;
        each window runs the §5 sparse program against its chunk while the
        next chunk's upload rides behind it (async `device_put`), and the
        window partials — the reduce monoid — accumulate on device.  The
        one host sync is `_sync` at the end: time-to-first-coadd no longer
        waits for the whole archive to land.
        """
        ds = self.dataset(plan.layout)
        exec_ds, _ = self.exec_dataset(plan.layout)
        gate = self._exec_gate(plan)
        if not gate.any():
            # Empty selection: answer zeros without building a window
            # schedule at all — no upload, no dispatch, and no window-stat
            # reduction over an empty list.
            return self._empty_streaming_result(plan)
        grid_ra, grid_dec = self._plan_grids(plan)
        block_rows = self._block_rows(plan.query, ds)
        windows = self._stream_windows(exec_ds, gate.any(axis=1))
        qvec = jnp.asarray(plan.qvec)
        m_builds0, d0 = self.matched_builds, self.dispatch_count

        def dispatch(dev, kern, win, dropped):
            g = gate
            if dropped:
                # Quarantined packs (§8): their pixels upload as zeros and
                # their slots gate False, so depth/files accounting excludes
                # them — the partial=True report is the honest answer.
                g = gate.copy()
                g[sorted(dropped)] = False
            self.dispatch_count += 1
            return _coadd_scan_sparse(
                dev.pixels,
                dev.wcs,
                dev.ints,
                dev.floats,
                kern,
                jnp.asarray(win.pack_idx),
                jnp.asarray(compact_window_gate(g, win)),
                qvec,
                grid_ra,
                grid_dec,
                use_kernel=self.use_kernel,
                block_rows=block_rows,
                interpret=self.kernel_interpret,
            )

        job_key = self._job_key(plan.method, plan.layout, gate, plan.qvec,
                                plan.query.npix, windows,
                                grid_tag=self._grid_tag(plan))
        (coadd, depth, contrib, considered), counters, elapsed, fc, quar = \
            self._run_stream_windows(plan.layout, exec_ds, windows, dispatch,
                                     job_key)
        uploads, hits, evictions = counters
        # Coverage honesty: only quarantined packs this query's gate actually
        # opens are *uncovered* for it — persistent quarantine on packs the
        # query never wanted is not a partial answer.
        quar = tuple(p for p in quar if gate[p].any())
        stats = JobStats(
            method=plan.method,
            files_considered=int(considered),
            files_contributing=int(contrib),
            packs_touched=plan.packs_touched,
            t_locate_s=plan.t_locate_s,
            t_map_reduce_s=elapsed,
            t_total_s=plan.t_locate_s + elapsed,
            dispatches=self.dispatch_count - d0,
            packs_gated=int(gate.any(axis=1).sum()),
            packs_scanned=sum(w.budget for w in windows),
            scan_budget=max(w.budget for w in windows),
            windows=len(windows),
            chunk_uploads=uploads,
            residency_hits=hits,
            residency_evictions=evictions,
            # In matched mode the chunk cache IS the matched-pixel cache:
            # a resident chunk hit reuses the convolution with the upload.
            matched_cache_builds=self.matched_builds - m_builds0,
            matched_cache_hits=hits if self._matched_mode() else 0,
            peak_resident_bytes=self._peak_resident_bytes(),
            retries=fc.retries,
            speculative_windows=fc.speculative_windows,
            quarantined_packs=fc.quarantined_packs,
            resumed_windows=fc.resumed_windows,
            partial=bool(quar),
            uncovered_packs=quar,
            requarantine_released=self._take_requarantine_released(),
        )
        return CoaddResult(np.asarray(coadd), np.asarray(depth), stats)

    def _reduce_tag(self, method: str, reduce: str, pass_tag: str) -> str:
        """Journal-identity tag of one robust pass: the method plus every
        engine knob that changes the pass's partial bytes, plus which pass
        this is — pass-1 moments and final clip partials of one query must
        never share a journal."""
        return (
            f"{method}|reduce={reduce}|k={self.clip_k}"
            f"|b={self.median_bins}|pass={pass_tag}"
        )

    def _execute_streaming_robust(self, plan: CoaddPlan) -> CoaddResult:
        """Robust reduce under a device budget: the multi-pass contract (§11).

        Each pass is an ordinary monoidal window stream: pass 1 accumulates
        the moments partials; ``median`` adds a binapprox-histogram pass;
        the final pass re-scans with the clip center/radius as fixed device
        operands.  Every pass journals under its own pass-tagged job key
        with ``keep_journal=True``, so a kill at ANY point — mid-pass or on
        the seam between passes — resumes by replaying the journaled
        windows bitwise; only when the final pass completes cleanly are all
        pass journals retired together.  Operands are recomputed from the
        replayed pass-1 partials on resume, so the recovered stack is
        bitwise-identical to the uninterrupted one.
        """
        ds = self.dataset(plan.layout)
        exec_ds, _ = self.exec_dataset(plan.layout)
        gate = self._exec_gate(plan)
        if not gate.any():
            res = self._empty_streaming_result(plan)
            res.stats.reduce = plan.reduce
            return res
        grid_ra, grid_dec = self._plan_grids(plan)
        block_rows = self._block_rows(plan.query, ds)
        windows = self._stream_windows(exec_ds, gate.any(axis=1))
        qvec = jnp.asarray(plan.qvec)
        m_builds0, d0 = self.matched_builds, self.dispatch_count
        up = hi = ev = 0
        elapsed = 0.0
        fc = FaultCounters()
        pass_keys: List[str] = []
        quar: Tuple[int, ...] = ()

        def run_pass(tag: str, pass_fn, *extra):
            nonlocal up, hi, ev, elapsed, quar

            def dispatch(dev, kern, win, dropped):
                g = gate
                if dropped:
                    g = gate.copy()
                    g[sorted(dropped)] = False
                self.dispatch_count += 1
                return pass_fn(
                    dev.pixels, dev.wcs, dev.ints, dev.floats, kern,
                    jnp.asarray(win.pack_idx),
                    jnp.asarray(compact_window_gate(g, win)),
                    qvec, grid_ra, grid_dec, *extra,
                    use_kernel=self.use_kernel, block_rows=block_rows,
                    interpret=self.kernel_interpret,
                )

            # Computed per pass, not once: a quarantine during an earlier
            # pass changes the registry, and this pass's partials must be
            # keyed by the pack set they actually scanned.
            job_key = self._job_key(
                self._reduce_tag(plan.method, plan.reduce, tag),
                plan.layout, gate, plan.qvec, plan.query.npix, windows,
                grid_tag=self._grid_tag(plan),
            )
            pass_keys.append(job_key)
            acc, counters, dt, pfc, pquar = self._run_stream_windows(
                plan.layout, exec_ds, windows, dispatch, job_key,
                keep_journal=True,
            )
            up, hi, ev = up + counters[0], hi + counters[1], ev + counters[2]
            elapsed += dt
            fc.retries += pfc.retries
            fc.speculative_windows += pfc.speculative_windows
            fc.quarantined_packs += pfc.quarantined_packs
            fc.resumed_windows += pfc.resumed_windows
            quar = tuple(sorted(set(quar) | set(pquar)))
            return acc

        clip_k = jnp.float32(self.clip_k)
        n_passes = 2
        s0, s1, s2, contrib, considered = run_pass(
            "moments", _moments_scan_sparse
        )
        if plan.reduce == "median":
            n_passes = 3
            lo, w, inv_w = _hist_operands(s0, s1, s2, nbins=self.median_bins)
            nb = self.median_bins
            (hist,) = run_pass(
                "hist",
                lambda *a, **kw: _hist_scan_sparse(*a, nbins=nb, **kw),
                lo, inv_w,
            )
            center, thresh = _median_operands(hist, s0, s1, s2, lo, w, clip_k)
        else:
            center, thresh = _clip_operands(s0, s1, s2, clip_k)
        coadd, depth = run_pass("clip", _clip_scan_sparse, center, thresh)
        # The whole job completed: every pass journal is now garbage.
        for key in pass_keys:
            self._retire_journal(key)
        quar = tuple(p for p in quar if gate[p].any())
        stats = JobStats(
            method=plan.method,
            files_considered=int(considered),
            files_contributing=int(contrib),
            packs_touched=plan.packs_touched,
            t_locate_s=plan.t_locate_s,
            t_map_reduce_s=elapsed,
            t_total_s=plan.t_locate_s + elapsed,
            dispatches=self.dispatch_count - d0,
            packs_gated=int(gate.any(axis=1).sum()),
            packs_scanned=n_passes * sum(w.budget for w in windows),
            scan_budget=max(w.budget for w in windows),
            windows=n_passes * len(windows),
            chunk_uploads=up,
            residency_hits=hi,
            residency_evictions=ev,
            matched_cache_builds=self.matched_builds - m_builds0,
            matched_cache_hits=hi if self._matched_mode() else 0,
            peak_resident_bytes=self._peak_resident_bytes(),
            retries=fc.retries,
            speculative_windows=fc.speculative_windows,
            quarantined_packs=fc.quarantined_packs,
            resumed_windows=fc.resumed_windows,
            partial=bool(quar),
            uncovered_packs=quar,
            requarantine_released=self._take_requarantine_released(),
            reduce=plan.reduce,
            reduce_passes=n_passes,
        )
        return CoaddResult(np.asarray(coadd), np.asarray(depth), stats)

    # ----- execution: one dispatch against resident data -----
    def execute(self, plan: CoaddPlan) -> CoaddResult:
        """One-dispatch query: device-resident packs + (P, cap) slot gate.

        With sparse execution on, the gate's padded pack-index vector is
        derived host-side and the jitted program gathers just those packs
        before scanning (`_coadd_scan_sparse`) — map work scales with
        `packs_gated` instead of the layout size, still in one dispatch.
        Under a device budget the query streams instead
        (`_execute_streaming`): windowed scans over budget-sized chunks.
        """
        self._check_plan_psf(plan)
        if self.device_budget_bytes is not None:
            if plan.reduce != "mean":
                return self._execute_streaming_robust(plan)
            return self._execute_streaming(plan)
        ds = self.dataset(plan.layout)
        exec_ds, _ = self.exec_dataset(plan.layout)
        dev = self.device_dataset(plan.layout)
        gate = self._exec_gate(plan)
        grid_ra, grid_dec = self._plan_grids(plan)
        block_rows = self._block_rows(plan.query, ds)
        psf_kernels = self._device_psf_kernels(plan.layout)
        m_builds0, m_hits = self.matched_builds, 0
        if self._matched_mode():
            # §7: the dispatch reads pre-matched resident pixels; no bank
            # operand, no per-query convolution.
            dev, m_hits = self._matched_device_dataset(plan.layout, dev)
            psf_kernels = None
        sp = self._sparse_index(gate)
        t1 = time.perf_counter()
        self.dispatch_count += 1
        if plan.reduce != "mean":
            # Robust eager path: all passes fused into ONE jitted dispatch
            # (the in-program re-scan is what keeps clipped within the
            # perf-gate overhead budget vs the mean).
            gate_dev = (jnp.asarray(compact_gate(gate, sp)) if sp is not None
                        else jnp.asarray(gate))
            pack_idx = jnp.asarray(sp.pack_idx) if sp is not None else None
            coadd, depth, contrib, considered = _robust_scan(
                dev.pixels,
                dev.wcs,
                dev.ints,
                dev.floats,
                psf_kernels,
                gate_dev,
                jnp.asarray(plan.qvec),
                grid_ra,
                grid_dec,
                jnp.float32(self.clip_k),
                use_kernel=self.use_kernel,
                block_rows=block_rows,
                interpret=self.kernel_interpret,
                reduce=plan.reduce,
                median_bins=self.median_bins,
                pack_idx=pack_idx,
            )
        elif sp is not None:
            coadd, depth, contrib, considered = _coadd_scan_sparse(
                dev.pixels,
                dev.wcs,
                dev.ints,
                dev.floats,
                psf_kernels,
                jnp.asarray(sp.pack_idx),
                jnp.asarray(compact_gate(gate, sp)),
                jnp.asarray(plan.qvec),
                grid_ra,
                grid_dec,
                use_kernel=self.use_kernel,
                block_rows=block_rows,
                interpret=self.kernel_interpret,
            )
        else:
            coadd, depth, contrib, considered = _coadd_scan(
                dev.pixels,
                dev.wcs,
                dev.ints,
                dev.floats,
                psf_kernels,
                jnp.asarray(gate),
                jnp.asarray(plan.qvec),
                grid_ra,
                grid_dec,
                use_kernel=self.use_kernel,
                block_rows=block_rows,
                interpret=self.kernel_interpret,
            )
        coadd.block_until_ready()
        t2 = time.perf_counter()
        scanned = sp.budget if sp is not None else exec_ds.n_packs
        stats = JobStats(
            method=plan.method,
            files_considered=int(considered),
            files_contributing=int(contrib),
            packs_touched=plan.packs_touched,
            t_locate_s=plan.t_locate_s,
            t_map_reduce_s=t2 - t1,
            t_total_s=plan.t_locate_s + (t2 - t1),
            dispatches=1,
            packs_gated=int(gate.any(axis=1).sum()),
            packs_scanned=scanned,
            scan_budget=scanned,
            matched_cache_builds=self.matched_builds - m_builds0,
            matched_cache_hits=m_hits,
            peak_resident_bytes=self._peak_resident_bytes(),
            reduce=plan.reduce,
        )
        return CoaddResult(np.asarray(coadd), np.asarray(depth), stats)

    def _eager_resident_bytes(self) -> int:
        """Device bytes resident *outside* the ResidencyManager: the eager
        whole-layout uploads (`_device_cache`) and device kernel banks.
        Added to the manager's peak in JobStats so eager matched mode —
        raw pixels AND their matched copy simultaneously resident — reports
        the true single-host footprint, not just the managed half."""
        total = 0
        for dev in self._device_cache.values():
            total += int(dev.pixels.nbytes) + int(dev.wcs.nbytes)
            total += sum(int(v.nbytes) for v in dev.ints.values())
            total += sum(int(v.nbytes) for v in dev.floats.values())
        total += sum(int(b.nbytes) for b in self._psf_device.values())
        return total

    def _peak_resident_bytes(self) -> int:
        """The JobStats peak: managed high-water mark + unmanaged eager
        residents (zero under a device budget, where nothing is eager)."""
        return self.residency.peak_bytes + self._eager_resident_bytes()

    def _check_plan_psf(self, plan: CoaddPlan) -> None:
        """A plan built under one PSF target must not run under another.

        Kernel banks and the matched-pixel cache are keyed per target, so
        executing a stale plan on a retuned engine would silently stack
        images homogenized to a different PSF than the plan promised.
        """
        if plan.psf_target != self.match_psf_sigma:
            raise ValueError(
                f"plan was built with psf_target={plan.psf_target} but this "
                f"engine matches to {self.match_psf_sigma}; re-plan on the "
                "engine that will execute"
            )

    def run(self, query: CoaddQuery, method: str,
            use_bricks: bool = False, reduce: str = "mean") -> CoaddResult:
        """Plan + execute one query.

        With ``use_bricks=True`` (DESIGN.md §9) a brick-aligned query is
        served by mosaicking cached brick coadds — materializing any
        missing bricks inline — and an unaligned query falls back to the
        ordinary path transparently (its stats carry zero brick counters).
        ``reduce`` picks the stacking estimator (DESIGN.md §11): "mean",
        "clipped" (k-sigma-clipped mean), or "median" (two-round
        median+clip); bricks are materialized and cached per estimator.
        """
        if use_bricks:
            res = self._run_bricks(query, method, reduce)
            if res is not None:
                return res
        return self.execute(self.plan(query, method, reduce))

    # ----- brick-tessellated materialized coadds (DESIGN.md §9) -----
    @property
    def brick_grid(self) -> BrickGrid:
        """The survey's brick tessellation (built lazily, fixed per engine)."""
        if self._brick_grid is None:
            self._brick_grid = BrickGrid.for_survey(
                self.survey.config, self.brick_deg, self.brick_npix
            )
        return self._brick_grid

    def _brick_key(self, band: str, row: int, col: int,
                   reduce: str = "mean") -> Tuple:
        """BrickStore identity of one materialized (brick, band) cell.

        Carries `_psf_state()` so a retuned engine misses and
        re-materializes instead of mosaicking tiles homogenized to a
        different target — staleness by key, the same contract as every
        other derived-residency cache.  Robust estimators extend the key
        (with their clip knobs — retuning k or the bin count must miss);
        mean keys stay exactly the pre-§11 shape so existing stores and
        spills remain valid.
        """
        key = ("brick", band, row, col, self._psf_state())
        if reduce != "mean":
            key += (reduce, self.clip_k, self.median_bins)
        return key

    def _brick_plan(self, band: str, row: int, col: int,
                    method: str, reduce: str = "mean") -> CoaddPlan:
        """The materialization plan for one brick: a normal planned query
        whose output grid is overridden onto the global lattice tile."""
        plan = self.plan(
            self.brick_grid.brick_query(row, col, band), method, reduce
        )
        plan.grid_sky = self.brick_grid.brick_sky(row, col)
        return plan

    def result_key(self, plan: CoaddPlan) -> str:
        """Serving-cache identity of one plan's result (DESIGN.md §10).

        The plan's value fingerprint (layout, grid, gate bytes, qvec bytes
        — `CoaddPlan.fingerprint`) joined with the engine state that also
        determines the pixels: the live PSF state (a retuned engine must
        miss, the same contract as every derived-residency cache) and the
        execution knobs that pick the program family (kernel vs XLA, sparse
        gather, streaming partition — float summation order differs across
        them, so bits may too).  Contract: equal keys ⇒ bitwise-equal
        coadds, so a serving layer may answer the second request from the
        first's cached output.
        """
        key = (
            f"{plan.fingerprint}|{self._psf_state()}"
            f"|k{int(self.use_kernel)}|s{int(self.sparse)}"
            f"|b{self.device_budget_bytes}"
        )
        if plan.reduce != "mean":
            # Robust knobs are engine state, not plan state — two engines
            # with different clip-k must not share a cached clipped stack.
            key += f"|ck{self.clip_k}|mb{self.median_bins}"
        return key

    def warm_brick_cover(self, query: CoaddQuery,
                         reduce: str = "mean") -> Optional[BrickCover]:
        """This query's brick cover iff *every* covered tile is stored.

        The serving front end routes such queries straight to the
        one-dispatch mosaic path (`run(use_bricks=True)`) — a guaranteed
        warm serve, never an inline materialization surprise under load.
        None when the query is unaligned or any tile is cold; the caller
        counts that miss into the `bricks_missed` popularity signal that
        decides what to materialize next (DESIGN.md §9/§10).
        """
        cover = self.brick_grid.decompose(query)
        if cover is None:
            return None
        store = self.brick_store
        if all(store.contains(self._brick_key(query.band, r, c, reduce))
               for r, c in cover.bricks):
            return cover
        return None

    def run_window(self, query: CoaddQuery, method: str,
                   reduce: str = "mean") -> CoaddResult:
        """The brick-free baseline for a brick-aligned query: one fresh
        scan onto the lattice-window grid.  This is the path
        `run(use_bricks=True)` must match bitwise — same lattice pixels,
        same gate semantics, no bricks consulted.  Raises on queries that
        do not decompose (use plain `run` for those)."""
        cover = self.brick_grid.decompose(query)
        if cover is None:
            raise ValueError(
                "query is not brick-aligned; run_window only serves "
                "lattice-window queries (see BrickGrid.window_query)"
            )
        plan = self.plan(query, method, reduce)
        plan.grid_sky = self.brick_grid.window_sky(
            cover.r0, cover.r1, cover.c0, cover.c1
        )
        return self.execute(plan)

    def _run_bricks(self, query: CoaddQuery, method: str,
                    reduce: str = "mean") -> Optional[CoaddResult]:
        """Serve a brick-aligned query from the BrickStore, or None.

        Decomposes the query into its brick cover, fetches every covered
        tile (device tier preferred, host-spill re-upload otherwise),
        freshly materializes the misses inline — each a normal `execute`
        under the full §8 fault domain, stored for the next query — and
        merges the tiles with one jitted weighted-sum mosaic dispatch.
        """
        cover = self.brick_grid.decompose(query)
        if cover is None:
            return None
        t0 = time.perf_counter()
        store = self.brick_store
        b = self.brick_npix
        d0 = self.dispatch_count
        hits = spills = 0
        tiles: List = []
        covs: List = []
        offsets: List[Tuple[int, int]] = []
        metas: List[Optional[BrickMeta]] = []
        missing: List[int] = []
        for i, (r, c) in enumerate(cover.bricks):
            offsets.append(((r - cover.r0) * b, (c - cover.c0) * b))
            got = store.fetch(self._brick_key(query.band, r, c, reduce))
            if got is None:
                missing.append(i)
                tiles.append(None)
                covs.append(None)
                metas.append(None)
                continue
            coadd_dev, depth_dev, meta, tier = got
            if tier == "device":
                hits += 1
            else:
                spills += 1
            tiles.append(coadd_dev)
            covs.append(depth_dev)
            metas.append(meta)
        t_fetch = time.perf_counter() - t0
        # The residual: bricks nobody materialized yet.  Each miss pays one
        # fresh streaming scan now and is cached for every query after.
        residual = JobStats("", 0, 0, 0, 0.0, 0.0, 0.0, dispatches=0)
        for i in missing:
            r, c = cover.bricks[i]
            res = self.execute(
                self._brick_plan(query.band, r, c, method, reduce)
            )
            meta = BrickMeta(
                partial=res.stats.partial,
                uncovered_packs=res.stats.uncovered_packs,
                files_considered=res.stats.files_considered,
                files_contributing=res.stats.files_contributing,
            )
            coadd_dev, depth_dev = store.put(
                self._brick_key(query.band, r, c, reduce),
                res.coadd, res.depth, meta,
            )
            tiles[i] = coadd_dev
            covs[i] = depth_dev
            metas[i] = meta
            s = res.stats
            residual.t_locate_s += s.t_locate_s
            residual.t_map_reduce_s += s.t_map_reduce_s
            residual.packs_touched += s.packs_touched
            residual.packs_gated += s.packs_gated
            residual.packs_scanned += s.packs_scanned
            residual.scan_budget = max(residual.scan_budget, s.scan_budget)
            residual.windows += s.windows
            residual.chunk_uploads += s.chunk_uploads
            residual.residency_hits += s.residency_hits
            residual.residency_evictions += s.residency_evictions
            residual.matched_cache_builds += s.matched_cache_builds
            residual.matched_cache_hits += s.matched_cache_hits
            residual.retries += s.retries
            residual.speculative_windows += s.speculative_windows
            residual.quarantined_packs += s.quarantined_packs
            residual.resumed_windows += s.resumed_windows
            residual.reduce_passes = max(residual.reduce_passes,
                                         s.reduce_passes)
        t1 = time.perf_counter()
        self.dispatch_count += 1
        coadd, depth = _mosaic_bricks(
            jnp.stack(tiles),
            jnp.stack(covs),
            jnp.asarray(np.array(offsets, np.int32)),
            query.npix,
            use_kernel=self.use_kernel,
            interpret=self.kernel_interpret,
        )
        coadd.block_until_ready()
        t2 = time.perf_counter()
        uncovered = sorted(
            {p for m in metas for p in m.uncovered_packs}
        )
        stats = JobStats(
            method=method,
            files_considered=sum(m.files_considered for m in metas),
            files_contributing=sum(m.files_contributing for m in metas),
            packs_touched=residual.packs_touched,
            t_locate_s=t_fetch + residual.t_locate_s,
            t_map_reduce_s=residual.t_map_reduce_s + (t2 - t1),
            t_total_s=(t2 - t0),
            dispatches=self.dispatch_count - d0,
            packs_gated=residual.packs_gated,
            packs_scanned=residual.packs_scanned,
            scan_budget=residual.scan_budget,
            windows=residual.windows,
            chunk_uploads=residual.chunk_uploads,
            residency_hits=residual.residency_hits,
            residency_evictions=residual.residency_evictions,
            matched_cache_builds=residual.matched_cache_builds,
            matched_cache_hits=residual.matched_cache_hits,
            peak_resident_bytes=self._peak_resident_bytes(),
            retries=residual.retries,
            speculative_windows=residual.speculative_windows,
            quarantined_packs=residual.quarantined_packs,
            resumed_windows=residual.resumed_windows,
            partial=any(m.partial for m in metas),
            uncovered_packs=tuple(uncovered),
            bricks_hit=hits,
            bricks_missed=len(missing),
            bricks_spilled=spills,
            residual_packs_scanned=residual.packs_scanned,
            reduce=reduce,
            reduce_passes=residual.reduce_passes if missing else 1,
        )
        return CoaddResult(np.asarray(coadd), np.asarray(depth), stats)

    def materialize_bricks(
        self,
        bands: Sequence[str] = ("r",),
        region: Optional[Tuple[Tuple[float, float], Tuple[float, float]]] = None,
        method: str = "sql_structured",
        reduce: str = "mean",
    ) -> MaterializeReport:
        """Batch-materialize the (brick, band) lattice into the BrickStore.

        Every cell is one normal planned+executed brick query driven
        through the streaming executors under the §8 fault domain, then
        journaled by its presence in the store: a killed job re-issued with
        the same arguments skips finished bricks and resumes the in-flight
        one from its window journal.  ``region=(ra_bounds, dec_bounds)``
        restricts to intersecting cells; bricks already materialized (same
        PSF state) are skipped.
        """
        grid = self.brick_grid
        cells = grid.bricks(region)
        tasks = [
            BrickTask(band=band, row=r, col=c)
            for band in bands for (r, c) in cells
        ]
        tracker = MaterializeTracker(
            max_attempts=self.fault_max_attempts,
            backoff_s=self.fault_backoff_s,
        )

        def is_done(task: BrickTask) -> bool:
            return self.brick_store.contains(
                self._brick_key(task.band, task.row, task.col, reduce)
            )

        def run_one(task: BrickTask) -> None:
            res = self.execute(
                self._brick_plan(task.band, task.row, task.col, method,
                                 reduce)
            )
            self.brick_store.put(
                self._brick_key(task.band, task.row, task.col, reduce),
                res.coadd,
                res.depth,
                BrickMeta(
                    partial=res.stats.partial,
                    uncovered_packs=res.stats.uncovered_packs,
                    files_considered=res.stats.files_considered,
                    files_contributing=res.stats.files_contributing,
                ),
            )
            task.status = "partial" if res.stats.partial else "done"
            task.packs_scanned = res.stats.packs_scanned
            task.retries = res.stats.retries
            task.resumed_windows = res.stats.resumed_windows

        return MaterializeReport(tracker.run(tasks, is_done, run_one))

    # ----- batched multi-query jobs (paper Fig. 5) -----
    def run_batch(
        self, queries: Sequence[CoaddQuery], method: str,
        reduce: str = "mean",
    ) -> List[CoaddResult]:
        """K same-method queries as ONE jitted dispatch over one layout."""
        queries = list(queries)
        if not queries:
            return []
        return self.execute_batch(
            [self.plan(q, method, reduce) for q in queries]
        )

    def execute_batch(self, plans: Sequence[CoaddPlan]) -> List[CoaddResult]:
        """Stacked plans -> one vmapped scan dispatch -> per-query results.

        Sparse batches compact against the *union* of the gates' packs
        (`union_sparse_index`), each query's compacted gate re-selecting its
        own slots — K queries remain ONE dispatch over one gathered layout.
        """
        plans = list(plans)
        for p in plans:
            self._check_plan_psf(p)
        gates, qvecs = stack_plans(plans)
        layout = plans[0].layout
        ds = self.dataset(layout)
        exec_ds, remap = self.exec_dataset(layout)
        if remap is not None:
            gates = np.stack([remap.apply(g) for g in gates])
        grids = [self._plan_grids(p) for p in plans]
        grids_ra = jnp.stack([g[0] for g in grids])
        grids_dec = jnp.stack([g[1] for g in grids])
        block_rows = self._block_rows(plans[0].query, ds)
        if self.device_budget_bytes is not None:
            return self._execute_batch_streaming(
                plans, exec_ds, gates, qvecs, grids_ra, grids_dec, block_rows
            )
        dev = self.device_dataset(layout)
        psf_kernels = self._device_psf_kernels(layout)
        m_builds0, m_hits = self.matched_builds, 0
        if self._matched_mode():
            dev, m_hits = self._matched_device_dataset(layout, dev)
            psf_kernels = None
        sp = self._sparse_index(gates)
        t1 = time.perf_counter()
        self.dispatch_count += 1
        if plans[0].reduce != "mean":
            # Robust batch, still ONE dispatch: the fused per-query passes
            # vmap over the stacked gates/grids (stack_plans guarantees one
            # shared reduce for the whole batch).
            gates_dev = (jnp.asarray(compact_gates(gates, sp))
                         if sp is not None else jnp.asarray(gates))
            pack_idx = jnp.asarray(sp.pack_idx) if sp is not None else None
            coadds, depths, contribs, considered = _robust_scan_batch(
                dev.pixels,
                dev.wcs,
                dev.ints,
                dev.floats,
                psf_kernels,
                gates_dev,
                jnp.asarray(qvecs),
                grids_ra,
                grids_dec,
                jnp.float32(self.clip_k),
                use_kernel=self.use_kernel,
                block_rows=block_rows,
                interpret=self.kernel_interpret,
                reduce=plans[0].reduce,
                median_bins=self.median_bins,
                pack_idx=pack_idx,
            )
        elif sp is not None:
            coadds, depths, contribs, considered = _coadd_scan_batch_sparse(
                dev.pixels,
                dev.wcs,
                dev.ints,
                dev.floats,
                psf_kernels,
                jnp.asarray(sp.pack_idx),
                jnp.asarray(compact_gates(gates, sp)),
                jnp.asarray(qvecs),
                grids_ra,
                grids_dec,
                use_kernel=self.use_kernel,
                block_rows=block_rows,
                interpret=self.kernel_interpret,
            )
        else:
            coadds, depths, contribs, considered = _coadd_scan_batch(
                dev.pixels,
                dev.wcs,
                dev.ints,
                dev.floats,
                psf_kernels,
                jnp.asarray(gates),
                jnp.asarray(qvecs),
                grids_ra,
                grids_dec,
                use_kernel=self.use_kernel,
                block_rows=block_rows,
                interpret=self.kernel_interpret,
            )
        coadds.block_until_ready()
        t2 = time.perf_counter()
        contribs = np.asarray(contribs)
        considered = np.asarray(considered)
        scanned = sp.budget if sp is not None else exec_ds.n_packs
        results = []
        for i, p in enumerate(plans):
            # One dispatch — and one wall-clock interval — serves the whole
            # batch; attribute both to the first result so summing stats
            # across the batch stays honest.
            t_mr = (t2 - t1) if i == 0 else 0.0
            stats = JobStats(
                method=p.method,
                files_considered=int(considered[i]),
                files_contributing=int(contribs[i]),
                packs_touched=p.packs_touched,
                t_locate_s=p.t_locate_s,
                t_map_reduce_s=t_mr,
                t_total_s=p.t_locate_s + t_mr,
                dispatches=1 if i == 0 else 0,
                packs_gated=int(gates[i].any(axis=1).sum()),
                packs_scanned=scanned if i == 0 else 0,
                scan_budget=scanned,
                matched_cache_builds=(self.matched_builds - m_builds0)
                if i == 0 else 0,
                matched_cache_hits=m_hits if i == 0 else 0,
                peak_resident_bytes=self._peak_resident_bytes(),
                reduce=p.reduce,
            )
            results.append(
                CoaddResult(np.asarray(coadds[i]), np.asarray(depths[i]), stats)
            )
        return results

    def _execute_batch_streaming(
        self, plans, exec_ds, gates, qvecs, grids_ra, grids_dec, block_rows
    ) -> List[CoaddResult]:
        """Windowed batch under a device budget (DESIGN.md §6).

        Windows come from the *union* of the K gates (one gathered chunk
        serves the whole batch, as in §5's union compaction); each window
        is one vmapped dispatch, partials accumulate per query, and the
        host syncs once at the end.
        """
        layout = plans[0].layout
        if plans[0].reduce != "mean" and gates.any():
            return self._execute_batch_streaming_robust(
                plans, exec_ds, gates, qvecs, grids_ra, grids_dec, block_rows
            )
        if not gates.any():
            # Empty union: every query selected nothing — answer zeros
            # without a window schedule (same contract as the single path).
            res = [self._empty_streaming_result(p) for p in plans]
            for p, r in zip(plans, res):
                r.stats.reduce = p.reduce
            return res
        union_any = gates.any(axis=0).any(axis=1)
        windows = self._stream_windows(exec_ds, union_any)
        qvecs_j = jnp.asarray(qvecs)
        m_builds0, d0 = self.matched_builds, self.dispatch_count

        def dispatch(dev, kern, win, dropped):
            g = gates
            if dropped:
                g = gates.copy()
                g[:, sorted(dropped)] = False
            self.dispatch_count += 1
            return _coadd_scan_batch_sparse(
                dev.pixels,
                dev.wcs,
                dev.ints,
                dev.floats,
                kern,
                jnp.asarray(win.pack_idx),
                jnp.asarray(compact_window_gates(g, win)),
                qvecs_j,
                grids_ra,
                grids_dec,
                use_kernel=self.use_kernel,
                block_rows=block_rows,
                interpret=self.kernel_interpret,
            )

        job_key = self._job_key(
            "batch:" + plans[0].method, layout, gates, qvecs, plans[0].npix,
            windows,
            grid_tag="|".join(self._grid_tag(p) for p in plans),
        )
        (coadds, depths, contribs, considered), counters, elapsed, fc, quar = \
            self._run_stream_windows(layout, exec_ds, windows, dispatch,
                                     job_key)
        uploads, hits, evictions = counters
        # Same coverage honesty as the single path: uncovered = quarantined
        # AND opened by at least one of the batch's gates.
        union_gate = gates.any(axis=0)
        quar = tuple(p for p in quar if union_gate[p].any())
        released = self._take_requarantine_released()
        contribs = np.asarray(contribs)
        considered = np.asarray(considered)
        scanned = sum(w.budget for w in windows)
        results = []
        for i, p in enumerate(plans):
            t_mr = elapsed if i == 0 else 0.0
            stats = JobStats(
                method=p.method,
                files_considered=int(considered[i]),
                files_contributing=int(contribs[i]),
                packs_touched=p.packs_touched,
                t_locate_s=p.t_locate_s,
                t_map_reduce_s=t_mr,
                t_total_s=p.t_locate_s + t_mr,
                dispatches=(self.dispatch_count - d0) if i == 0 else 0,
                packs_gated=int(gates[i].any(axis=1).sum()),
                packs_scanned=scanned if i == 0 else 0,
                scan_budget=max(w.budget for w in windows),
                windows=len(windows),
                chunk_uploads=uploads if i == 0 else 0,
                residency_hits=hits if i == 0 else 0,
                residency_evictions=evictions if i == 0 else 0,
                matched_cache_builds=(self.matched_builds - m_builds0)
                if i == 0 else 0,
                matched_cache_hits=hits
                if (i == 0 and self._matched_mode()) else 0,
                peak_resident_bytes=self._peak_resident_bytes(),
                # Fault counters are additive -> first result; quarantine
                # coverage loss affects every query in the batch -> all.
                retries=fc.retries if i == 0 else 0,
                speculative_windows=fc.speculative_windows if i == 0 else 0,
                quarantined_packs=fc.quarantined_packs if i == 0 else 0,
                resumed_windows=fc.resumed_windows if i == 0 else 0,
                partial=bool(quar),
                uncovered_packs=quar,
                requarantine_released=released if i == 0 else 0,
            )
            results.append(
                CoaddResult(np.asarray(coadds[i]), np.asarray(depths[i]), stats)
            )
        return results

    def _execute_batch_streaming_robust(
        self, plans, exec_ds, gates, qvecs, grids_ra, grids_dec, block_rows
    ) -> List[CoaddResult]:
        """Robust batch under a device budget: the §11 multi-pass contract
        over the union window schedule.  Same journaling/retirement rules
        as `_execute_streaming_robust`, vmapped over the batch's queries
        (per-query clip operands ride the batch axis between passes)."""
        layout = plans[0].layout
        reduce = plans[0].reduce
        union_any = gates.any(axis=0).any(axis=1)
        windows = self._stream_windows(exec_ds, union_any)
        qvecs_j = jnp.asarray(qvecs)
        m_builds0, d0 = self.matched_builds, self.dispatch_count
        up = hi = ev = 0
        elapsed = 0.0
        fc = FaultCounters()
        pass_keys: List[str] = []
        quar: Tuple[int, ...] = ()

        def run_pass(tag: str, pass_fn, *extra):
            nonlocal up, hi, ev, elapsed, quar

            def dispatch(dev, kern, win, dropped):
                g = gates
                if dropped:
                    g = gates.copy()
                    g[:, sorted(dropped)] = False
                self.dispatch_count += 1
                return pass_fn(
                    dev.pixels, dev.wcs, dev.ints, dev.floats, kern,
                    jnp.asarray(win.pack_idx),
                    jnp.asarray(compact_window_gates(g, win)),
                    qvecs_j, grids_ra, grids_dec, *extra,
                    use_kernel=self.use_kernel, block_rows=block_rows,
                    interpret=self.kernel_interpret,
                )

            job_key = self._job_key(
                "batch:" + self._reduce_tag(plans[0].method, reduce, tag),
                layout, gates, qvecs, plans[0].npix, windows,
                grid_tag="|".join(self._grid_tag(p) for p in plans),
            )
            pass_keys.append(job_key)
            acc, counters, dt, pfc, pquar = self._run_stream_windows(
                layout, exec_ds, windows, dispatch, job_key,
                keep_journal=True,
            )
            up, hi, ev = up + counters[0], hi + counters[1], ev + counters[2]
            elapsed += dt
            fc.retries += pfc.retries
            fc.speculative_windows += pfc.speculative_windows
            fc.quarantined_packs += pfc.quarantined_packs
            fc.resumed_windows += pfc.resumed_windows
            quar = tuple(sorted(set(quar) | set(pquar)))
            return acc

        clip_k = jnp.float32(self.clip_k)
        n_passes = 2
        s0, s1, s2, contribs, considered = run_pass(
            "moments", _moments_scan_batch_sparse
        )
        if reduce == "median":
            n_passes = 3
            nb = self.median_bins
            los, ws, inv_ws = _hist_operands(s0, s1, s2, nbins=nb)
            (hists,) = run_pass(
                "hist",
                lambda *a, **kw: _hist_scan_batch_sparse(*a, nbins=nb, **kw),
                los, inv_ws,
            )
            centers, threshs = jax.vmap(
                _median_operands, in_axes=(0, 0, 0, 0, 0, 0, None)
            )(hists, s0, s1, s2, los, ws, clip_k)
        else:
            centers, threshs = _clip_operands(s0, s1, s2, clip_k)
        coadds, depths = run_pass(
            "clip", _clip_scan_batch_sparse, centers, threshs
        )
        for key in pass_keys:
            self._retire_journal(key)
        union_gate = gates.any(axis=0)
        quar = tuple(p for p in quar if union_gate[p].any())
        released = self._take_requarantine_released()
        contribs = np.asarray(contribs)
        considered = np.asarray(considered)
        scanned = n_passes * sum(w.budget for w in windows)
        results = []
        for i, p in enumerate(plans):
            t_mr = elapsed if i == 0 else 0.0
            stats = JobStats(
                method=p.method,
                files_considered=int(considered[i]),
                files_contributing=int(contribs[i]),
                packs_touched=p.packs_touched,
                t_locate_s=p.t_locate_s,
                t_map_reduce_s=t_mr,
                t_total_s=p.t_locate_s + t_mr,
                dispatches=(self.dispatch_count - d0) if i == 0 else 0,
                packs_gated=int(gates[i].any(axis=1).sum()),
                packs_scanned=scanned if i == 0 else 0,
                scan_budget=max(w.budget for w in windows),
                windows=n_passes * len(windows),
                chunk_uploads=up if i == 0 else 0,
                residency_hits=hi if i == 0 else 0,
                residency_evictions=ev if i == 0 else 0,
                matched_cache_builds=(self.matched_builds - m_builds0)
                if i == 0 else 0,
                matched_cache_hits=hi
                if (i == 0 and self._matched_mode()) else 0,
                peak_resident_bytes=self._peak_resident_bytes(),
                retries=fc.retries if i == 0 else 0,
                speculative_windows=fc.speculative_windows if i == 0 else 0,
                quarantined_packs=fc.quarantined_packs if i == 0 else 0,
                resumed_windows=fc.resumed_windows if i == 0 else 0,
                partial=bool(quar),
                uncovered_packs=quar,
                requarantine_released=released if i == 0 else 0,
                reduce=p.reduce,
                reduce_passes=n_passes,
            )
            results.append(
                CoaddResult(np.asarray(coadds[i]), np.asarray(depths[i]), stats)
            )
        return results

    # ----- distributed (production) path -----
    def run_distributed(
        self,
        queries: Sequence[CoaddQuery],
        mesh: Mesh,
        data_axes: Tuple[str, ...] = ("data",),
        model_axis: Optional[str] = "model",
    ) -> List[CoaddResult]:
        """Multi-query MapReduce over a device mesh.

        The structured layout is sharded over the data axes ONCE
        (`mesh_dataset`; cached per mesh) so repeat jobs move zero pixel
        bytes; each job ships per-query flat slot gates (exact spatial-index
        selection, i.e. the paper's best method), every device maps the
        *gated* entries of its resident slab (per-shard local compaction —
        dense fallback maps the whole slab), and reduction is psum over data
        axes + reduce-scatter of output rows over the model axis
        (`reducer.py`).
        """
        queries = list(queries)
        if not queries:
            return []
        npix = queries[0].npix
        if any(q.npix != npix for q in queries):
            raise ValueError("all queries in one job must share npix")
        model_size = mesh.shape[model_axis] if model_axis else 1
        if npix % max(model_size, 1):
            raise ValueError(f"npix={npix} must divide by model axis {model_size}")

        # Images are sharded over *every* mesh axis (map work on all devices);
        # the reduction then psums over the data axes and reduce-scatters over
        # the model axis, leaving each model shard a band of the coadd.
        shard_axes = tuple(data_axes) + ((model_axis,) if model_axis else ())
        ds = self.dataset("structured")
        t0 = time.perf_counter()
        id_sets = [self.sql.select(q) for q in queries]
        nonempty = [i for i in id_sets if len(i)]
        all_ids = (
            np.unique(np.concatenate(nonempty)) if nonempty
            else np.array([], np.int64)
        )
        t_locate = time.perf_counter() - t0
        if len(all_ids) == 0:
            # Nothing overlaps any query: answer with zero coadds instead of
            # padding a phantom image through the map stage.
            stats = lambda: JobStats(  # noqa: E731
                method="distributed_sql_structured",
                files_considered=0,
                files_contributing=0,
                packs_touched=0,
                t_locate_s=t_locate,
                t_map_reduce_s=0.0,
                t_total_s=t_locate,
                dispatches=0,
            )
            return [
                CoaddResult(
                    np.zeros((npix, npix), np.float32),
                    np.zeros((npix, npix), np.float32),
                    stats(),
                )
                for _ in queries
            ]

        n_shards = shard_count(mesh, shard_axes)
        exec_ds, _ = self.exec_dataset("structured")
        pad_to = exec_ds.flat_len(n_shards)
        t0 = time.perf_counter()
        # Per-job host->mesh traffic: gates + qvecs + grids. No pixels.
        gates = np.stack(
            [ds.flat_slot_mask(ids, pad_to=pad_to) for ids in id_sets]
        )
        t_locate += time.perf_counter() - t0
        block_rows = self._block_rows(queries[0], ds)
        grids = np.stack([np.stack(mapper.query_grid_sky(q)) for q in queries])
        qvecs = np.stack([_query_vec(q) for q in queries])  # (nq, 7)
        nq = len(queries)

        # Flat-axis residency windows (DESIGN.md §6).  With no budget the
        # whole archive shards once ([0, M) via the mesh_dataset cache, a
        # pixel upload outside the locate window so first-job and repeat-job
        # stats stay comparable).  Under a per-device budget the flat axis
        # streams in shard-aligned windows sized so two per-shard slabs —
        # scanning and uploading — fit the budget (double buffering).
        img_bytes = max(
            (exec_ds.pack_nbytes() + self._bank_pack_nbytes("structured"))
            // max(exec_ds.capacity, 1),
            1,
        )
        if self.device_budget_bytes is None:
            flat_windows = [(0, pad_to)]
        else:
            per_shard = max(1, int(self.device_budget_bytes // (2 * img_bytes)))
            win_flat = min(pad_to, per_shard * n_shards)
            flat_windows = [
                (a, min(a + win_flat, pad_to))
                for a in range(0, pad_to, win_flat)
            ]
            if self.sparse:
                union = gates.any(axis=0)
                flat_windows = [
                    (a, b) for a, b in flat_windows if union[a:b].any()
                ] or flat_windows[:1]

        meta_keys_i = tuple(sorted(exec_ds.ints.keys()))
        meta_keys_f = tuple(sorted(exec_ds.floats.keys()))
        use_kernel = self.use_kernel
        interpret = self.kernel_interpret
        in_spec = P(shard_axes)
        out_rows = P(None, model_axis) if model_axis else P(None)

        def window_job(mds, gates_exec, local_idx, budgets, tile, local_len):
            """One shard_map dispatch over one resident flat window."""
            idx_t = (
                () if local_idx is None
                else (jnp.asarray(local_idx.reshape(-1)),)
            )
            bud_t = () if local_idx is None else (jnp.asarray(budgets),)
            kern_t = () if mds.psf_kernels is None else (mds.psf_kernels,)

            def job(px, wv, ints_flat, floats_flat, kern_t, idx_t, bud_t,
                    gates, qvecs, grids):
                ints = dict(zip(meta_keys_i, ints_flat))
                floats = dict(zip(meta_keys_f, floats_flat))
                kern = kern_t[0] if kern_t else None
                npix_q = grids.shape[-1]

                def collect(c, d):
                    return reducer.reduce_collective(
                        c, d, axis_name=data_axes, scatter_axis_name=model_axis
                    )

                if not idx_t:
                    # Dense fallback: map the whole resident slab.
                    def one_query(gate, qvec, grid):
                        accept = _accept_from_meta(ints, floats, qvec) & gate
                        tiles, covs = mapper.map_batch(
                            px, wv, accept, grid[0], grid[1],
                            use_kernel=use_kernel, block_rows=block_rows,
                            interpret=interpret, psf_kernels=kern,
                        )
                        return collect(*reducer.reduce_local(tiles, covs))

                    return jax.vmap(one_query)(gates, qvecs, grids)

                # Local compaction with per-shard budgets (DESIGN.md §5/§6):
                # the gather+map runs in `tile`-sized steps and each shard's
                # fori_loop stops at its OWN bucketed budget — a quiet shard
                # gathers and maps only its own gated entries, not the
                # busiest shard's worth.  The psum/scatter collectives sit
                # after the loop, so divergent trip counts never desync the
                # collective schedule.
                idx = idx_t[0]            # (shared_budget,) local indices
                my_budget = bud_t[0][0]   # () this shard's own bucket

                def tile_step(t, acc):
                    c_acc, d_acc = acc
                    sl = jax.lax.dynamic_slice(idx, (t * tile,), (tile,))
                    px_t = jnp.take(px, sl, axis=0)
                    wv_t = jnp.take(wv, sl, axis=0)
                    ints_t = {k: jnp.take(v, sl, axis=0)
                              for k, v in ints.items()}
                    floats_t = {k: jnp.take(v, sl, axis=0)
                                for k, v in floats.items()}
                    kern_tile = (
                        None if kern is None else jnp.take(kern, sl, axis=0)
                    )
                    gates_t = jax.lax.dynamic_slice(
                        gates, (0, t * tile), (nq, tile)
                    )

                    def one_query(gate, qvec, grid):
                        accept = _accept_from_meta(ints_t, floats_t, qvec) & gate
                        tiles, covs = mapper.map_batch(
                            px_t, wv_t, accept, grid[0], grid[1],
                            use_kernel=use_kernel, block_rows=block_rows,
                            interpret=interpret, psf_kernels=kern_tile,
                        )
                        return reducer.reduce_local(tiles, covs)

                    c, d = jax.vmap(one_query)(gates_t, qvecs, grids)
                    return (c_acc + c, d_acc + d)

                init = (
                    jnp.zeros((nq, npix_q, npix_q), jnp.float32),
                    jnp.zeros((nq, npix_q, npix_q), jnp.float32),
                )
                n_tiles = (my_budget + tile - 1) // tile
                c, d = jax.lax.fori_loop(0, n_tiles, tile_step, init)
                return jax.vmap(collect)(c, d)

            # vmap-of-psum under the VMA/rep checker is broken across jax
            # versions (psum_invariant rejects axis_index_groups); check=False.
            shard = shard_map_compat(
                job,
                mesh=mesh,
                in_specs=(
                    in_spec,
                    in_spec,
                    (in_spec,) * len(meta_keys_i),
                    (in_spec,) * len(meta_keys_f),
                    (in_spec,) * len(kern_t),
                    (in_spec,) * len(idx_t),
                    (in_spec,) * len(bud_t),
                    P(None, shard_axes),
                    P(None),
                    P(None),
                ),
                out_specs=(out_rows, out_rows),
                check=False,
            )
            self.dispatch_count += 1
            return shard(
                mds.pixels,
                mds.wcs,
                tuple(mds.ints[k] for k in meta_keys_i),
                tuple(mds.floats[k] for k in meta_keys_f),
                kern_t,
                idx_t,
                bud_t,
                jnp.asarray(gates_exec),
                jnp.asarray(qvecs),
                jnp.asarray(grids),
            )

        def mesh_window(a: int, b: int) -> MeshResidentDataset:
            if self.device_budget_bytes is None:
                return self.mesh_dataset("structured", mesh, shard_axes)
            key = ("mesh", "structured", mesh, tuple(shard_axes), a, b,
                   self._psf_state())

            def build():
                self.mesh_upload_count += 1
                return exec_ds.to_mesh_window(
                    mesh, tuple(shard_axes), a, b,
                    psf_kernels=self.psf_kernel_bank("structured"),
                )

            # Budget accounting is per device: each shard holds 1/n_shards
            # of the window.
            return self.residency.acquire(
                key, (b - a) // n_shards * img_bytes, build
            )

        up0, hit0, ev0 = (self.residency.uploads, self.residency.hits,
                          self.residency.evictions)
        # Eager path: the one-time whole-layout shard (a pixel upload, not
        # job init) stays outside the timed window so first-job and
        # repeat-job stats are comparable — mirroring how execute() leaves
        # device_dataset untimed.  Streaming windows upload *inside* it:
        # the overlapped transfer is exactly what time-to-first-coadd
        # measures.
        if self.device_budget_bytes is None:
            mds = mesh_window(*flat_windows[0])
        t1 = time.perf_counter()
        if self.device_budget_bytes is not None:
            mds = mesh_window(*flat_windows[0])
        coadds = depths = None
        packs_scanned = 0
        scan_budget_max = 0
        shards_touched = np.zeros((nq,), np.int64)
        for i, (a, b) in enumerate(flat_windows):
            local_len = (b - a) // n_shards
            gates_w = gates[:, a:b]
            # Per-shard local compaction (DESIGN.md §5): each shard gathers
            # only the slab entries some query in the job selected; shipped
            # per-query gates are compacted to the same local coordinates,
            # padding masked False.
            local_idx = budgets = None
            tile = local_len
            budget_w = local_len
            if self.sparse:
                local_idx, pad_mask, budget, budgets = shard_local_compaction(
                    gates_w.any(axis=0), n_shards
                )
                if budget < local_len:
                    budget_w = budget
                    # Tile size: a power-of-two divisor of the shared budget
                    # (in this branch every per-shard bucket is a pure power
                    # of two < local_len), floored at budget/8 so the tile
                    # loop never degenerates into one-image steps.  Shards
                    # run ceil(own_budget/tile) tiles; slack rows past a
                    # shard's own budget are 0-padded, gate-False entries.
                    tile = max(int(budgets.min()), budget // 8)
                    per_shard = gates_w.reshape(nq, n_shards, local_len)
                    gates_exec = (
                        np.take_along_axis(per_shard, local_idx[None], axis=2)
                        & pad_mask[None]
                    ).reshape(nq, n_shards * budget)
                else:
                    local_idx = budgets = None
            if local_idx is None:
                gates_exec = gates_w
            c, d = window_job(mds, gates_exec, local_idx, budgets, tile,
                              local_len)
            coadds = c if coadds is None else coadds + c
            depths = d if depths is None else depths + d
            packs_scanned += (
                int(((budgets + tile - 1) // tile * tile).sum())
                if budgets is not None else n_shards * local_len
            )
            scan_budget_max = max(scan_budget_max, budget_w)
            # Locality stats derive from the *flat* gate the mesh actually
            # executes: pack identity is lost in the flattened layout, so
            # the honest "containers opened" count is resident (window,
            # shard) slabs touched (see JobStats.packs_touched).
            shards_touched += gates_w.reshape(nq, n_shards, local_len).any(
                axis=2
            ).sum(axis=1)
            if i + 1 < len(flat_windows):
                mds = mesh_window(*flat_windows[i + 1])  # prefetch next slab
        _sync(coadds)
        t2 = time.perf_counter()

        results = []
        for qi, q in enumerate(queries):
            stats = JobStats(
                method="distributed_sql_structured",
                files_considered=len(all_ids),
                files_contributing=len(id_sets[qi]),
                packs_touched=int(shards_touched[qi]),
                t_locate_s=t_locate,
                t_map_reduce_s=t2 - t1,
                t_total_s=t_locate + (t2 - t1),
                # One windowed shard_map job serves the whole multi-query
                # batch; attribute it to the first result so summing stats
                # is honest.
                dispatches=len(flat_windows) if qi == 0 else 0,
                packs_gated=int(shards_touched[qi]),
                packs_scanned=packs_scanned if qi == 0 else 0,
                scan_budget=scan_budget_max,
                windows=len(flat_windows),
                chunk_uploads=(self.residency.uploads - up0) if qi == 0 else 0,
                residency_hits=(self.residency.hits - hit0) if qi == 0 else 0,
                residency_evictions=(self.residency.evictions - ev0)
                if qi == 0 else 0,
                peak_resident_bytes=self._peak_resident_bytes(),
            )
            results.append(
                CoaddResult(np.asarray(coadds[qi]), np.asarray(depths[qi]), stats)
            )
        return results
