"""CoaddEngine: the paper's MapReduce coaddition job, end to end.

Implements all six input-format strategies of Table 1 / Table 2 so the
benchmarks can reproduce the paper's comparisons measurably:

  1. ``raw_fits``                 — per-file dispatch, no prefilter (the
                                    paper only estimated this row; we measure)
  2. ``raw_fits_prefiltered``     — glob (band x camcol) prefilter, then
                                    per-file dispatch            (§4.1.1)
  3. ``unstructured_seq``         — packed containers, random layout; no
                                    pruning possible; all packs read (§4.1.2)
  4. ``structured_seq_prefiltered``— containers keyed by (band, camcol);
                                    container-level glob pruning (§4.1.3)
  5. ``sql_unstructured``         — exact spatial-index selection gathered
                                    from the unstructured containers (§4.1.4)
  6. ``sql_structured``           — exact selection gathered from structured
                                    containers (better locality -> fewer
                                    containers touched)          (§4.1.4)

Device-resident pipeline (DESIGN.md §3): the paper's lesson is that per-file
overhead dominates and packing amortizes it.  The seed engine reproduced the
*storage* side of that lesson but reintroduced the overhead on the *compute*
side — a Python loop paying one host->device transfer and one jit dispatch
per pack, the "per-record RPC" pathology the paper eliminates.  Here every
layout is uploaded to device **once** and cached; every query is answered by
**one** jitted `lax.scan` over packs, driven by a static-shape (P, cap)
boolean slot gate.  Per-query dispatches are O(1) in the number of packs and
the only per-query host->device traffic is the gate + query vector + output
grid.  The six methods differ *only* in how the gate is built (and in the
host-side locate cost of building it), which is exactly the paper's framing:
input format determines job-init cost, not mapper arithmetic.

`run_distributed` is the production path: images sharded over the
(``pod`` x) ``data`` axes via `shard_map`, map stage local, reduction by
psum + reduce-scatter (see `reducer.py`).  Multiple queries are processed in
one job (paper Fig. 5) by stacking query grids.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import mapper, reducer
from repro.core.prefilter import (
    SpatialIndex,
    camcol_dec_table,
    glob_file_mask,
    glob_pack_mask,
)
from repro.core.query import CoaddQuery
from repro.core.seqfile import (
    DevicePackedDataset,
    PackedDataset,
    pack_per_file,
    pack_structured,
    pack_unstructured,
)
from repro.core.survey import Survey
from repro.distributed.sharding import shard_map_compat
from repro.kernels.warp import ops as warp_ops

METHODS = (
    "raw_fits",
    "raw_fits_prefiltered",
    "unstructured_seq",
    "structured_seq_prefiltered",
    "sql_unstructured",
    "sql_structured",
)


@dataclasses.dataclass
class JobStats:
    method: str
    files_considered: int          # mapper input records (Table 2)
    files_contributing: int        # actual coverage
    packs_touched: int             # "mapper objects" locality proxy (§4.1.4)
    t_locate_s: float              # job-init: prefilter/index/gather ("RPC")
    t_map_reduce_s: float          # device compute
    t_total_s: float
    dispatches: int = 1            # jitted device dispatches for this query


@dataclasses.dataclass
class CoaddResult:
    coadd: np.ndarray
    depth: np.ndarray
    stats: JobStats

    @property
    def normalized(self) -> np.ndarray:
        return np.where(self.depth > 0, self.coadd / np.maximum(self.depth, 1e-6), 0.0)


def _query_vec(query: CoaddQuery) -> np.ndarray:
    t0, t1 = query.time_window()
    # Large-but-finite sentinels keep the vector finite for jit friendliness.
    t0 = max(t0, -1e30)
    t1 = min(t1, 1e30)
    return np.array(
        [
            float(query.band_id),
            query.ra_bounds[0],
            query.ra_bounds[1],
            query.dec_bounds[0],
            query.dec_bounds[1],
            t0,
            t1,
        ],
        np.float32,
    )


def _accept_from_meta(ints, floats, qvec):
    band_ok = ints["band_id"].astype(jnp.float32) == qvec[0]
    valid = ints["image_id"] >= 0
    ra_ok = (floats["ra_max"] >= qvec[1]) & (floats["ra_min"] <= qvec[2])
    dec_ok = (floats["dec_max"] >= qvec[3]) & (floats["dec_min"] <= qvec[4])
    t_ok = (floats["t_obs"] >= qvec[5]) & (floats["t_obs"] <= qvec[6])
    return band_ok & valid & ra_ok & dec_ok & t_ok


@partial(jax.jit, static_argnames=("use_kernel",))
def _coadd_batch(pixels, wcs, ints, floats, qvec, grid_ra, grid_dec, use_kernel=False):
    """Map+local-reduce one dense batch of images. The jitted inner job."""
    accept = _accept_from_meta(ints, floats, qvec)
    tiles, covs = mapper.map_batch(
        pixels, wcs, accept, grid_ra, grid_dec, use_kernel=use_kernel
    )
    coadd, depth = reducer.reduce_local(tiles, covs)
    return coadd, depth, accept.sum()


@partial(jax.jit, static_argnames=("use_kernel", "block_rows", "interpret"))
def _coadd_scan(
    pixels,      # (P, cap, H, W) device-resident
    wcs,         # (P, cap, 8)
    ints,        # dict of (P, cap) int32
    floats,      # dict of (P, cap) float32
    gate,        # (P, cap) bool — static shape, dynamic values
    qvec,        # (7,)
    grid_ra,     # (Q, Q)
    grid_dec,    # (Q, Q)
    use_kernel=False,
    block_rows=8,
    interpret=True,
):
    """The whole query in ONE XLA program: scan packs, fuse map+reduce.

    The scan carries (coadd, depth, contributing); each step gates a pack's
    slots by metadata acceptance AND the caller's slot gate, projects, and
    accumulates locally — so the (N, Q, Q) tile stack never materializes
    across packs and the dispatch count is 1 regardless of n_packs.
    Non-gated slots contribute exact zeros (masked SPMD discard, Fig. 6).
    Counts come back as device scalars: no per-pack host syncs.
    """

    def step(carry, xs):
        coadd, depth, contrib = carry
        px, wv, ints_p, floats_p, gate_p = xs
        accept = _accept_from_meta(ints_p, floats_p, qvec) & gate_p
        if use_kernel:
            c, d = warp_ops.coadd_fused(
                px,
                wv,
                accept.astype(jnp.float32),
                grid_ra,
                grid_dec,
                block_rows=block_rows,
                interpret=interpret,
            )
        else:
            tiles, covs = mapper.map_batch(px, wv, accept, grid_ra, grid_dec)
            c, d = reducer.reduce_local(tiles, covs)
        return (coadd + c, depth + d, contrib + accept.sum()), None

    q = grid_ra.shape[0]
    init = (
        jnp.zeros((q, q), jnp.float32),
        jnp.zeros((q, q), jnp.float32),
        jnp.zeros((), jnp.int32),
    )
    (coadd, depth, contrib), _ = jax.lax.scan(
        step, init, (pixels, wcs, ints, floats, gate)
    )
    return coadd, depth, contrib, gate.sum()


class CoaddEngine:
    """Builds the three dataset layouts once, then answers queries 6 ways.

    Pixels cross host->device exactly once per layout (`device_dataset`);
    every `run` is a single jitted dispatch (`_coadd_scan`).  Set
    ``use_kernel=True`` to fuse map+reduce through the Pallas ``coadd_fused``
    kernel (``kernel_interpret=False`` on real TPUs lowers through Mosaic).
    """

    def __init__(
        self,
        survey: Survey,
        pack_capacity: int = 64,
        use_kernel: bool = False,
        block_rows: Optional[int] = None,
        kernel_interpret: bool = True,
    ):
        self.survey = survey
        self.use_kernel = use_kernel
        self.block_rows = block_rows  # None -> autotune per (npix, H, W)
        self.kernel_interpret = kernel_interpret
        self.camcol_dec = camcol_dec_table(survey)
        self.sql = SpatialIndex.build(survey)
        self._datasets: Dict[str, PackedDataset] = {}
        self._device_cache: Dict[str, DevicePackedDataset] = {}
        self._pack_capacity = pack_capacity
        self.pack_upload_count = 0   # host->device uploads of pack pixels
        self.dispatch_count = 0      # jitted device dispatches issued

    # ----- dataset layouts (built lazily, cached) -----
    def dataset(self, layout: str) -> PackedDataset:
        if layout not in self._datasets:
            if layout == "per_file":
                self._datasets[layout] = pack_per_file(self.survey)
            elif layout == "unstructured":
                self._datasets[layout] = pack_unstructured(
                    self.survey, self._pack_capacity
                )
            elif layout == "structured":
                self._datasets[layout] = pack_structured(
                    self.survey, self._pack_capacity
                )
            else:
                raise ValueError(layout)
        return self._datasets[layout]

    def device_dataset(self, layout: str) -> DevicePackedDataset:
        """Device-resident form of a layout; uploaded once, then cached."""
        if layout not in self._device_cache:
            self._device_cache[layout] = self.dataset(layout).to_device()
            self.pack_upload_count += 1
        return self._device_cache[layout]

    # ----- shared helpers -----
    def _grids(self, query: CoaddQuery):
        gr, gd = mapper.query_grid_sky(query)
        return jnp.asarray(gr), jnp.asarray(gd)

    def _block_rows(self, query: CoaddQuery, ds: PackedDataset) -> int:
        if self.block_rows is not None:
            return self.block_rows
        h, w = ds.image_hw()
        return warp_ops.autotune_block_rows(query.npix, h, w)

    def _run_gated(
        self,
        layout: str,
        gate_np: np.ndarray,
        query: CoaddQuery,
        t_locate: float,
        method: str,
    ) -> CoaddResult:
        """One-dispatch query: device-resident packs + (P, cap) slot gate."""
        ds = self.dataset(layout)
        dev = self.device_dataset(layout)
        grid_ra, grid_dec = self._grids(query)
        qvec = jnp.asarray(_query_vec(query))
        gate = jnp.asarray(gate_np)
        block_rows = self._block_rows(query, ds)
        t1 = time.perf_counter()
        self.dispatch_count += 1
        coadd, depth, contrib, considered = _coadd_scan(
            dev.pixels,
            dev.wcs,
            dev.ints,
            dev.floats,
            gate,
            qvec,
            grid_ra,
            grid_dec,
            use_kernel=self.use_kernel,
            block_rows=block_rows,
            interpret=self.kernel_interpret,
        )
        coadd.block_until_ready()
        t2 = time.perf_counter()
        stats = JobStats(
            method=method,
            files_considered=int(considered),
            files_contributing=int(contrib),
            packs_touched=int(gate_np.any(axis=1).sum()),
            t_locate_s=t_locate,
            t_map_reduce_s=t2 - t1,
            t_total_s=t_locate + (t2 - t1),
            dispatches=1,
        )
        return CoaddResult(np.asarray(coadd), np.asarray(depth), stats)

    # ----- the six methods (they differ only in gate construction) -----
    def run(self, query: CoaddQuery, method: str) -> CoaddResult:
        if method not in METHODS:
            raise ValueError(f"unknown method {method}; expected one of {METHODS}")
        return getattr(self, f"_run_{method}")(query)

    def _run_raw_fits(self, query: CoaddQuery) -> CoaddResult:
        ds = self.dataset("per_file")
        t0 = time.perf_counter()
        # No prefilter: every file is "located" and becomes a mapper input.
        gate = ds.valid.copy()
        t_locate = time.perf_counter() - t0
        return self._run_gated("per_file", gate, query, t_locate, "raw_fits")

    def _run_raw_fits_prefiltered(self, query: CoaddQuery) -> CoaddResult:
        ds = self.dataset("per_file")
        t0 = time.perf_counter()
        mask = glob_file_mask(self.survey.meta_table(), query, self.camcol_dec)
        gate = ds.valid & mask[:, None]  # per-file layout: pack == file
        t_locate = time.perf_counter() - t0
        return self._run_gated(
            "per_file", gate, query, t_locate, "raw_fits_prefiltered"
        )

    def _run_unstructured_seq(self, query: CoaddQuery) -> CoaddResult:
        ds = self.dataset("unstructured")
        t0 = time.perf_counter()
        gate = ds.valid.copy()  # unprunable by construction: read every pack
        t_locate = time.perf_counter() - t0
        return self._run_gated("unstructured", gate, query, t_locate, "unstructured_seq")

    def _run_structured_seq_prefiltered(self, query: CoaddQuery) -> CoaddResult:
        ds = self.dataset("structured")
        t0 = time.perf_counter()
        mask = glob_pack_mask(ds, query, self.camcol_dec)
        gate = ds.valid & mask[:, None]
        t_locate = time.perf_counter() - t0
        return self._run_gated(
            "structured", gate, query, t_locate, "structured_seq_prefiltered"
        )

    def _sql_gather(self, layout: str, query: CoaddQuery, method: str) -> CoaddResult:
        ds = self.dataset(layout)
        t0 = time.perf_counter()
        ids = self.sql.select(query)
        # The index maps ids -> (pack, slot); the "gather" is now a
        # metadata-only slot gate over the device-resident containers, so
        # exact selection costs no pixel movement at all.
        gate = ds.slot_mask(ids)
        t_locate = time.perf_counter() - t0
        return self._run_gated(layout, gate, query, t_locate, method)

    def _run_sql_unstructured(self, query: CoaddQuery) -> CoaddResult:
        return self._sql_gather("unstructured", query, "sql_unstructured")

    def _run_sql_structured(self, query: CoaddQuery) -> CoaddResult:
        return self._sql_gather("structured", query, "sql_structured")

    # ----- distributed (production) path -----
    def run_distributed(
        self,
        queries: Sequence[CoaddQuery],
        mesh: Mesh,
        data_axes: Tuple[str, ...] = ("data",),
        model_axis: Optional[str] = "model",
    ) -> List[CoaddResult]:
        """Multi-query MapReduce over a device mesh.

        Images (exact-index-selected, i.e. the paper's best method) are
        sharded over the data axes; every device maps its local images for
        every query; reduction is psum over data axes + reduce-scatter of
        output rows over the model axis.
        """
        npix = queries[0].npix
        if any(q.npix != npix for q in queries):
            raise ValueError("all queries in one job must share npix")
        model_size = mesh.shape[model_axis] if model_axis else 1
        if npix % max(model_size, 1):
            raise ValueError(f"npix={npix} must divide by model axis {model_size}")

        # Images are sharded over *every* mesh axis (map work on all devices);
        # the reduction then psums over the data axes and reduce-scatters over
        # the model axis, leaving each model shard a band of the coadd.
        shard_axes = tuple(data_axes) + ((model_axis,) if model_axis else ())
        ds = self.dataset("structured")
        block_rows = self._block_rows(queries[0], ds)
        t0 = time.perf_counter()
        id_sets = [self.sql.select(q) for q in queries]
        all_ids = np.unique(np.concatenate([i for i in id_sets if len(i)]))
        n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))
        pad_to = int(np.ceil(max(len(all_ids), 1) / n_shards) * n_shards)
        px, wv, ints_np, floats_np, valid, n_packs = ds.gather(all_ids, pad_to=pad_to)
        t_locate = time.perf_counter() - t0

        grids = np.stack([np.stack(mapper.query_grid_sky(q)) for q in queries])
        qvecs = np.stack([_query_vec(q) for q in queries])  # (nq, 7)

        in_spec = P(shard_axes)
        meta_keys_i = tuple(sorted(ints_np.keys()))
        meta_keys_f = tuple(sorted(floats_np.keys()))
        use_kernel = self.use_kernel
        interpret = self.kernel_interpret

        def job(px, wv, ints_flat, floats_flat, qvecs, grids):
            ints = dict(zip(meta_keys_i, ints_flat))
            floats = dict(zip(meta_keys_f, floats_flat))

            def one_query(qvec, grid):
                accept = _accept_from_meta(ints, floats, qvec)
                tiles, covs = mapper.map_batch(
                    px,
                    wv,
                    accept,
                    grid[0],
                    grid[1],
                    use_kernel=use_kernel,
                    block_rows=block_rows,
                    interpret=interpret,
                )
                c, d = reducer.reduce_local(tiles, covs)
                return reducer.reduce_collective(
                    c, d, axis_name=data_axes, scatter_axis_name=model_axis
                )
            return jax.vmap(one_query)(qvecs, grids)

        out_rows = P(None, model_axis) if model_axis else P(None)
        # vmap-of-psum under the VMA/rep checker is broken across jax
        # versions (psum_invariant rejects axis_index_groups); check=False.
        shard = shard_map_compat(
            job,
            mesh=mesh,
            in_specs=(
                in_spec,
                in_spec,
                (in_spec,) * len(meta_keys_i),
                (in_spec,) * len(meta_keys_f),
                P(None),
                P(None),
            ),
            out_specs=(out_rows, out_rows),
            check=False,
        )
        t1 = time.perf_counter()
        self.dispatch_count += 1
        coadds, depths = shard(
            jnp.asarray(px),
            jnp.asarray(wv),
            tuple(jnp.asarray(ints_np[k]) for k in meta_keys_i),
            tuple(jnp.asarray(floats_np[k]) for k in meta_keys_f),
            jnp.asarray(qvecs),
            jnp.asarray(grids),
        )
        coadds.block_until_ready()
        t2 = time.perf_counter()

        results = []
        for qi, q in enumerate(queries):
            stats = JobStats(
                method="distributed_sql_structured",
                files_considered=len(all_ids),
                files_contributing=len(id_sets[qi]),
                packs_touched=n_packs,
                t_locate_s=t_locate,
                t_map_reduce_s=t2 - t1,
                t_total_s=t_locate + (t2 - t1),
                # One shard_map dispatch serves the whole multi-query job;
                # attribute it to the first result so summing stats is honest.
                dispatches=1 if qi == 0 else 0,
            )
            results.append(
                CoaddResult(np.asarray(coadds[qi]), np.asarray(depths[qi]), stats)
            )
        return results
