"""Durable journals: crash-safe window partials and a persistent brick spill.

Hadoop's robustness (paper §3) comes from *materializing* intermediate task
outputs to worker-local disk: losing a process — not just failing a task —
loses no finished work.  PR 6's fault domain journals window partials only
in memory, so a SIGKILL/OOM restarts a query from zero; this module is the
disk half of that contract (DESIGN.md §8):

* `JournalStore` / `DiskJournal` — per-job append-only journals of window
  partials.  Each job key owns a directory holding a `segment.bin` of raw
  npy payload records and a `manifest.jsonl` with one line per committed record
  (window key, byte range, sha256).  A record is committed by appending its
  payload bytes and *then* its manifest line (each flushed to the OS), so a
  crash at any byte leaves either a fully committed record or
  an ignorable tail.  Replay walks the manifest and stops at the first
  invalid record — truncated line, out-of-range payload, or digest mismatch
  — and truncates both files back to that valid prefix: corrupted tails
  degrade to re-execution, never to a crash or a wrong bit.

* `BrickSpill` — the persistent host tier of the `BrickStore`: one
  atomically renamed npz per brick carrying its own content digest.  Reload
  verifies the digest; any failure (torn write, bit flip, truncation)
  deletes the file and reports a miss, so the brick simply rematerializes.

The engine opts in with ``CoaddEngine(journal_dir=...)``; the in-memory
default keeps its zero-sync clean path.  Commits are synchronous but
flush-only: each record lands in the page cache (durable across process
death — the SIGKILL drills' failure model), and the fsync pair is deferred
to the ``drain`` barrier the engine invokes on the fatal path, narrowing
the *power-loss* window to the tail of a query instead of paying ~0.5 ms
per record.  The ``durable_overhead`` BENCH rows gate the clean path at
≤1.15x the in-memory tracker.

Crash-drill seam: `set_crash_hook` installs a callable invoked with a stage
name at every durability boundary (``payload_mid``, ``payload_done``,
``manifest_done``, ``brick_done``).  The subprocess drills in
`tests/test_durable.py` SIGKILL themselves there — including *mid* segment
write — and assert a fresh process resumes bitwise.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

# Stages, in commit order, at which `_crash` fires (see module docstring).
CRASH_STAGES = ("payload_mid", "payload_done", "manifest_done", "brick_done")

_CRASH_HOOK: Optional[Callable[[str], None]] = None


def set_crash_hook(hook: Optional[Callable[[str], None]]) -> None:
    """Install (or clear, with None) the crash-drill hook.

    Test-only seam: the hook runs inside the durability commit sequence, so
    a hook that SIGKILLs its own process models a crash at exactly that
    boundary.  Production never sets it; the clean-path cost is one global
    load per stage.
    """
    global _CRASH_HOOK
    _CRASH_HOOK = hook


def _crash(stage: str) -> None:
    if _CRASH_HOOK is not None:
        _CRASH_HOOK(stage)


def _fsync_dir(path: Path) -> None:
    """fsync a directory so a just-renamed/created entry survives power loss.

    Best-effort: some filesystems refuse O_RDONLY on directories; the rename
    itself is still atomic there, only its durability window widens.
    """
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write_bytes(path: Path, data: bytes, fsync: bool = True) -> None:
    """Write ``data`` to ``path`` via temp file + fsync + atomic rename.

    ``fsync=False`` skips both fsyncs for *advisory* files (e.g. a job's
    ``meta.json``): the rename stays atomic — the file is never torn — but
    its durability window widens to the next OS writeback.
    """
    tmp = path.with_name(f"{path.name}.tmp.{os.getpid()}")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        _fsync_dir(path.parent)


def _encode_arrays(arrays: Tuple[np.ndarray, ...]) -> bytes:
    """One blob for a window's partial-accumulator tuple.

    A raw npy stream — count byte, then each array in `numpy.lib.format` —
    rather than npz: the zip container costs ~0.4 ms per record on the
    journal's hot path and buys nothing (the manifest already carries the
    sha256; names and compression don't apply to a 3-tuple of partials).
    """
    bio = io.BytesIO()
    bio.write(bytes([len(arrays)]))
    for a in arrays:
        np.lib.format.write_array(
            bio, np.asarray(a), allow_pickle=False
        )
    return bio.getvalue()


def _decode_arrays(data: bytes) -> Tuple[np.ndarray, ...]:
    bio = io.BytesIO(data)
    n = bio.read(1)[0]
    return tuple(
        np.lib.format.read_array(bio, allow_pickle=False) for _ in range(n)
    )


class DiskJournal:
    """One job's on-disk window journal (dict-like; see `WindowTracker.run`).

    Keys are window keys — tuples of ints ``(start, stop, n_gated,
    budget)`` — and values are window partial tuples.  `__setitem__`
    materializes the partial to host and *commits* it: payload append +
    flush, then manifest line + flush.  A flush makes the record durable
    against process death (SIGKILL, OOM — the page cache survives); the
    fsync pair that makes it durable against power loss is deferred to the
    `drain` barrier, which the engine runs on the fatal path (the moment an
    orphaned journal starts to matter).  A record lost to an unsynced
    power cut just tears the tail — replay truncates back to the valid
    prefix and the windows re-execute.

    Commit errors (disk full, permissions) are recorded in ``error`` and
    the record stays in-memory only: a broken journal downgrades
    durability, never the answer.

    Opening replays the valid manifest prefix and truncates any invalid
    tail of both files, so an instance is always consistent with its disk
    state; ``dropped_records`` counts records a corrupted tail discarded.
    """

    SEGMENT = "segment.bin"
    MANIFEST = "manifest.jsonl"

    def __init__(self, root: Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._seg_path = self.root / self.SEGMENT
        self._man_path = self.root / self.MANIFEST
        self._entries: Dict[Tuple[int, ...], Tuple[np.ndarray, ...]] = {}
        self._seg_f = None
        self._man_f = None
        self.error: Optional[BaseException] = None
        self.dropped_records = 0
        self._replay()

    # ----- replay: valid prefix only, truncate the rest -----
    def _replay(self) -> None:
        if not self._man_path.exists():
            self._seg_end = 0
            if self._seg_path.exists():
                # Manifest lost/never written: nothing is committed.
                self._truncate(self._seg_path, 0)
            return
        seg = self._seg_path.read_bytes() if self._seg_path.exists() else b""
        man_valid = seg_valid = 0
        with open(self._man_path, "rb") as f:
            for raw in f:
                if not raw.endswith(b"\n"):
                    break  # torn final line: not committed
                try:
                    rec = json.loads(raw)
                    key = tuple(int(k) for k in rec["win"])
                    off, ln = int(rec["off"]), int(rec["len"])
                    sha = rec["sha"]
                except (ValueError, KeyError, TypeError):
                    break
                if off != seg_valid or off + ln > len(seg):
                    break  # gap or truncated payload
                payload = seg[off:off + ln]
                if hashlib.sha256(payload).hexdigest() != sha:
                    break  # bit rot in the payload (or a stale manifest)
                try:
                    self._entries[key] = _decode_arrays(payload)
                except Exception:
                    break  # undecodable despite digest: stale format
                man_valid += len(raw)
                seg_valid = off + ln
        self.dropped_records = max(
            self._count_lines(self._man_path) - len(self._entries), 0
        )
        # Truncate both files to the committed prefix so appends restart
        # from a consistent byte offset.
        self._truncate(self._man_path, man_valid)
        self._truncate(self._seg_path, seg_valid)
        self._seg_end = seg_valid

    @staticmethod
    def _count_lines(path: Path) -> int:
        try:
            with open(path, "rb") as f:
                return sum(1 for _ in f)
        except OSError:
            return 0

    @staticmethod
    def _truncate(path: Path, size: int) -> None:
        if path.exists() and path.stat().st_size > size:
            with open(path, "r+b") as f:
                f.truncate(size)
                f.flush()
                os.fsync(f.fileno())

    # ----- append path -----
    def _files(self):
        if self._seg_f is None:
            self._seg_f = open(self._seg_path, "ab")
            self._man_f = open(self._man_path, "ab")
        return self._seg_f, self._man_f

    def __setitem__(self, key, parts) -> None:
        norm = tuple(int(k) for k in key)
        host = tuple(np.asarray(p) for p in parts)  # device sync: the cost
        try:
            self._commit(norm, host)
        except BaseException as e:
            self.error = e  # durability lost; the entry stays in-memory
        self._entries[norm] = host

    def _commit(self, key: Tuple[int, ...],
                host: Tuple[np.ndarray, ...]) -> None:
        payload = _encode_arrays(host)
        sha = hashlib.sha256(payload).hexdigest()
        seg_f, man_f = self._files()
        off = self._seg_end
        half = len(payload) // 2
        seg_f.write(payload[:half])
        seg_f.flush()
        _crash("payload_mid")  # a crash here leaves an uncommitted tail
        seg_f.write(payload[half:])
        seg_f.flush()
        _crash("payload_done")  # payload flushed, record not yet committed
        line = json.dumps(
            {"win": list(key), "off": off, "len": len(payload), "sha": sha}
        )
        man_f.write(line.encode() + b"\n")
        man_f.flush()
        self._seg_end = off + len(payload)
        _crash("manifest_done")  # record committed (process-death durable)

    def drain(self) -> None:
        """The power-loss durability barrier: fsync both files.

        Per-record commits only flush (cheap, survives process death); the
        engine drains on the fatal path — the one moment an orphaned
        journal is about to become load-bearing — so everything committed
        before the fault also survives a machine crash.
        """
        for f in (self._seg_f, self._man_f):
            if f is not None:
                try:
                    f.flush()
                    os.fsync(f.fileno())
                except OSError as e:  # pragma: no cover - defensive
                    self.error = e

    # ----- dict-like reads (the tracker's journal contract) -----
    def __contains__(self, key) -> bool:
        return tuple(int(k) for k in key) in self._entries

    def __getitem__(self, key):
        return self._entries[tuple(int(k) for k in key)]

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> Iterator[Tuple[int, ...]]:
        return iter(self._entries)

    def close(self) -> None:
        for f in (self._seg_f, self._man_f):
            if f is not None:
                f.close()
        self._seg_f = self._man_f = None


class JournalStore:
    """Directory of `DiskJournal`s keyed by job key, with GC.

    Layout: ``root/<job_key[:32]>/{meta.json, segment.bin, manifest.jsonl}``.
    ``meta.json`` (atomic-rename write) records the full job key and
    creation time.  `remove` retires a completed job atomically: the
    directory is renamed aside first, so a crash mid-delete never leaves a
    half-journal a resume could misread.  `sweep_stale` (run at engine
    init) deletes orphans older than ``max_age_s`` plus any rename/temp
    debris — completed jobs remove their journals, so orphans are only
    crashed jobs nobody resumed.
    """

    def __init__(self, root, max_age_s: float = 7 * 86400.0):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.max_age_s = float(max_age_s)
        self._tomb_seq = 0
        self._reaper: Optional[threading.Thread] = None
        self._tombs: "queue.Queue" = queue.Queue()
        self.swept = self.sweep_stale()

    def _job_dir(self, job_key: str) -> Path:
        return self.root / job_key[:32]

    def exists(self, job_key: str) -> bool:
        return (self._job_dir(job_key) / DiskJournal.MANIFEST).exists()

    def open(self, job_key: str) -> DiskJournal:
        d = self._job_dir(job_key)
        journal = DiskJournal(d)
        meta = d / "meta.json"
        if not meta.exists():
            # Advisory, for humans inspecting the store: the dir name is
            # the identity and nothing machine-reads this, so a plain write
            # (torn on crash at worst) beats paying tmp+rename per query.
            meta.write_bytes(
                json.dumps(
                    {"job_key": job_key, "created": time.time()}
                ).encode()
            )
        return journal

    def remove(self, job_key: str) -> bool:
        """Atomically retire a job's journal (clean-exit GC).

        The rename is the retirement — one atomic step and the journal can
        never be resumed.  The actual deletion is handed to a background
        reaper thread so completion doesn't pay rmtree latency; a tomb that
        outlives the process is just debris the next `sweep_stale` eats.
        """
        d = self._job_dir(job_key)
        if not d.exists():
            return False
        self._tomb_seq += 1
        tomb = d.with_name(f"{d.name}.gc.{os.getpid()}.{self._tomb_seq}")
        try:
            os.rename(d, tomb)  # atomic: the journal vanishes in one step
        except OSError:
            return False
        self._tombs.put(tomb)
        if self._reaper is None or not self._reaper.is_alive():
            self._reaper = threading.Thread(
                target=self._reap, name="journal-reaper", daemon=True
            )
            self._reaper.start()
        return True

    def _reap(self) -> None:
        while True:
            try:
                tomb = self._tombs.get(timeout=5.0)
            except queue.Empty:
                return  # idle: let the thread retire; remove() respawns it
            shutil.rmtree(tomb, ignore_errors=True)
            self._tombs.task_done()

    def drain_tombs(self) -> None:
        """Block until every queued tomb has been deleted (test sync point)."""
        self._tombs.join()

    def jobs(self) -> List[str]:
        return sorted(
            p.name for p in self.root.iterdir()
            if p.is_dir() and ".gc." not in p.name
        )

    def sweep_stale(self, max_age_s: Optional[float] = None) -> int:
        """Delete orphan journals older than the age cap (+ any debris)."""
        cap = self.max_age_s if max_age_s is None else float(max_age_s)
        now = time.time()
        swept = 0
        for p in list(self.root.iterdir()):
            if ".gc." in p.name or ".tmp." in p.name:
                # Debris from an interrupted remove/atomic write.
                shutil.rmtree(p, ignore_errors=True)
                if not p.is_dir():
                    p.unlink(missing_ok=True)
                swept += 1
                continue
            if not p.is_dir():
                continue
            try:
                age = now - p.stat().st_mtime
            except OSError:
                continue
            if age > cap:
                shutil.rmtree(p, ignore_errors=True)
                swept += 1
        return swept


class BrickSpill:
    """Persistent, self-checksummed host spill for materialized bricks.

    One npz per brick key — coadd, depth, a json-encoded meta dict, and a
    sha256 over all three — written via temp file + fsync + atomic rename,
    so a file either exists whole or not at all.  `load` re-verifies the
    digest and treats *any* failure as a miss (deleting the bad file): a
    corrupted brick costs a rematerialization, never a crash or a wrong
    mosaic.
    """

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.corrupt_drops = 0  # reloads rejected by digest/decode failure

    def _path(self, key: Tuple) -> Path:
        tag = hashlib.sha256(repr(key).encode()).hexdigest()[:24]
        return self.root / f"brick-{tag}.npz"

    @staticmethod
    def _digest(coadd: np.ndarray, depth: np.ndarray, meta_raw: bytes) -> str:
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(coadd, np.float32).tobytes())
        h.update(np.ascontiguousarray(depth, np.float32).tobytes())
        h.update(meta_raw)
        return h.hexdigest()

    def save(self, key: Tuple, coadd: np.ndarray, depth: np.ndarray,
             meta: Dict) -> None:
        meta_raw = json.dumps(meta, sort_keys=True).encode()
        bio = io.BytesIO()
        np.savez(
            bio,
            coadd=np.asarray(coadd, np.float32),
            depth=np.asarray(depth, np.float32),
            meta=np.frombuffer(meta_raw, np.uint8),
            sha=np.frombuffer(
                self._digest(coadd, depth, meta_raw).encode(), np.uint8
            ),
            keyrepr=np.frombuffer(repr(key).encode(), np.uint8),
        )
        _atomic_write_bytes(self._path(key), bio.getvalue())
        _crash("brick_done")

    def load(
        self, key: Tuple
    ) -> Optional[Tuple[np.ndarray, np.ndarray, Dict]]:
        path = self._path(key)
        if not path.exists():
            return None
        try:
            with np.load(path) as z:
                coadd = np.asarray(z["coadd"], np.float32)
                depth = np.asarray(z["depth"], np.float32)
                meta_raw = z["meta"].tobytes()
                sha = z["sha"].tobytes().decode()
            if self._digest(coadd, depth, meta_raw) != sha:
                raise ValueError("digest mismatch")
            return coadd, depth, json.loads(meta_raw)
        except Exception:
            # Corrupt/truncated/unreadable: drop it and report a miss —
            # the caller rematerializes.
            self.corrupt_drops += 1
            path.unlink(missing_ok=True)
            return None

    def contains(self, key: Tuple) -> bool:
        return self._path(key).exists()

    def delete(self, key: Tuple) -> None:
        self._path(key).unlink(missing_ok=True)

    def clear(self) -> None:
        for p in self.root.glob("brick-*.npz"):
            p.unlink(missing_ok=True)


__all__ = [
    "CRASH_STAGES",
    "BrickSpill",
    "DiskJournal",
    "JournalStore",
    "set_crash_hook",
]
