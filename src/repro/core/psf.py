"""PSF matching (beyond-paper; the paper deferred it — their footnote 2).

Before stacking, exposures taken in different seeing should be convolved to
a common (worst) PSF so the coadd has a well-defined point-spread function.
We implement the Gaussian-to-Gaussian case: if an image has PSF sigma_i and
the target is sigma_t >= sigma_i, convolving with a Gaussian of
sigma_k = sqrt(sigma_t^2 - sigma_i^2) matches them exactly (Gaussians are
closed under convolution).

Separable implementation (two 1-D convs) — O(H*W*K) and jit/vmap-friendly;
the engine applies it per image in the map stage when
``CoaddEngine(..., match_psf_sigma=...)`` is set.  Because the matching
widths vary per image but jit demands static shapes, the engine
host-precomputes a *kernel bank* — one (K,) row per pack slot, all sharing
the dataset-wide max radius, delta rows where no widening is needed
(`matching_kernel_bank`) — and passes it to the map stage as a plain
operand, in both the XLA path (`convolve_batch`) and the Pallas
`coadd_fused` kernel (in-kernel banded-matmul convolution).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gaussian_kernel_1d(sigma: float, radius: int | None = None) -> jnp.ndarray:
    if sigma <= 0:
        return jnp.ones((1,), jnp.float32)
    if radius is None:
        radius = max(1, int(np.ceil(3.0 * sigma)))
    x = jnp.arange(-radius, radius + 1, dtype=jnp.float32)
    k = jnp.exp(-0.5 * (x / sigma) ** 2)
    return k / k.sum()


def matching_kernel_bank(
    psf_sigmas: np.ndarray, sigma_target: float, radius: int | None = None
) -> np.ndarray:
    """Per-slot 1-D matching kernels, one static-width bank for a dataset.

    ``psf_sigmas`` is any-shaped (...,) array of per-image PSF widths; the
    result is (..., K) with K = 2*radius + 1 shared across slots (static
    shapes for jit / Pallas operands).  Slots already at/above the target
    (and empty slots with sigma 0 treated alike) get an exact delta row, so
    applying the bank is a no-op for them — the "no-op when
    sigma_target <= sigma_image" rule of `match_psf`, vectorized.
    """
    s = np.asarray(psf_sigmas, np.float64)
    # sigma <= 0 marks an empty/padded slot, not an infinitely sharp image:
    # give it a delta row and keep it out of the bank-radius computation so
    # phantom slots can't widen K for the whole layout.
    sig_k = np.where(
        s > 0, np.sqrt(np.maximum(sigma_target**2 - s**2, 0.0)), 0.0
    )
    if radius is None:
        radius = int(np.ceil(3.0 * float(sig_k.max(initial=0.0))))
    k_width = 2 * radius + 1
    x = np.arange(-radius, radius + 1, dtype=np.float64)
    delta = (x == 0).astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        g = np.exp(-0.5 * (x / np.where(sig_k == 0, 1.0, sig_k)[..., None]) ** 2)
    bank = np.where((sig_k > 0)[..., None], g, delta)
    bank = bank / bank.sum(axis=-1, keepdims=True)
    assert bank.shape == s.shape + (k_width,)
    return bank.astype(np.float32)


def convolve_separable(image: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """(H, W) image * 1-D kernel applied along both axes (edge-padded)."""
    r = (kernel.shape[0] - 1) // 2

    def conv1d(row):
        return jnp.convolve(jnp.pad(row, (r, r), mode="edge"), kernel, mode="valid")

    out = jax.vmap(conv1d)(image)          # rows
    out = jax.vmap(conv1d)(out.T).T        # cols
    return out


def convolve_batch(images: jnp.ndarray, kernels: jnp.ndarray) -> jnp.ndarray:
    """(N, H, W) images, each convolved with its own (K,) kernel row.

    The per-image kernels come from `matching_kernel_bank`; a delta row makes
    the convolution exact identity up to float rounding.  K == 1 (a bank with
    zero max radius, i.e. nothing to widen) short-circuits to a multiply.
    """
    if kernels.shape[-1] == 1:
        return images * kernels[..., 0][:, None, None]
    return jax.vmap(convolve_separable)(images, kernels)


def match_psf(image: jnp.ndarray, sigma_image: float, sigma_target: float) -> jnp.ndarray:
    """Convolve to the target PSF. No-op if already at/above target width."""
    if sigma_target <= sigma_image:
        return image
    sigma_k = float(np.sqrt(sigma_target**2 - sigma_image**2))
    return convolve_separable(image, gaussian_kernel_1d(sigma_k))
