"""PSF matching and homogenization (the paper deferred it — footnote 2).

Before stacking, exposures taken in different seeing should be convolved to
a common (worst) PSF so the coadd has a well-defined point-spread function.
Two regimes, one bank contract:

* **Gaussian-to-Gaussian** (`matching_kernel_bank`): if an image has PSF
  sigma_i and the target is sigma_t >= sigma_i, convolving with a Gaussian
  of sigma_k = sqrt(sigma_t^2 - sigma_i^2) matches them exactly (Gaussians
  are closed under convolution).  Separable — one (K,) row per slot.

* **Measured-PSF homogenization** (`homogenization_bank`): production
  co-addition can't assume Gaussian optics; each exposure carries an
  *empirical* PSF stamp (survey.py synthesizes elliptical Moffats).  The
  Lupton-style matching kernel k solving ``stamp * k = target`` is found by
  regularized least squares in Fourier space — a ridge term keeps the
  effective deconvolution bounded where the stamp's transform runs out of
  power — then cropped to a static (K, K) tap grid and renormalized to unit
  sum (flux conservation).  Stamps already broader than the target clamp to
  delta rows with a warning: matching *never deconvolves* (monotone).  One
  non-separable (K, K) kernel per slot.

Because per-image kernels vary but jit demands static shapes, the engine
host-precomputes the bank — delta rows where no widening is needed — and
passes it to the map stage as a plain operand, in both the XLA path
(`convolve_batch`, which dispatches on bank rank: (N, K) separable rows vs
(N, K, K) full 2-D taps) and the Pallas `coadd_fused` kernel (in-kernel
banded-matmul convolution; 1-D and 2-D variants).  All paths share one
convention — cross-correlation with edge-clamped sampling:
``out[i, j] = sum_{m,n} k[m, n] * img[clip(i+m-r), clip(j+n-r)]``.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np


def gaussian_kernel_1d(sigma: float, radius: int | None = None) -> jnp.ndarray:
    if sigma <= 0:
        return jnp.ones((1,), jnp.float32)
    if radius is None:
        radius = max(1, int(np.ceil(3.0 * sigma)))
    x = jnp.arange(-radius, radius + 1, dtype=jnp.float32)
    k = jnp.exp(-0.5 * (x / sigma) ** 2)
    return k / k.sum()


def matching_kernel_bank(
    psf_sigmas: np.ndarray, sigma_target: float, radius: int | None = None
) -> np.ndarray:
    """Per-slot 1-D matching kernels, one static-width bank for a dataset.

    ``psf_sigmas`` is any-shaped (...,) array of per-image PSF widths; the
    result is (..., K) with K = 2*radius + 1 shared across slots (static
    shapes for jit / Pallas operands).  Slots already at/above the target
    (and empty slots with sigma 0 treated alike) get an exact delta row, so
    applying the bank is a no-op for them — the "no-op when
    sigma_target <= sigma_image" rule of `match_psf`, vectorized.
    """
    s = np.asarray(psf_sigmas, np.float64)
    # sigma <= 0 marks an empty/padded slot, not an infinitely sharp image:
    # give it a delta row and keep it out of the bank-radius computation so
    # phantom slots can't widen K for the whole layout.
    sig_k = np.where(
        s > 0, np.sqrt(np.maximum(sigma_target**2 - s**2, 0.0)), 0.0
    )
    if radius is None:
        radius = int(np.ceil(3.0 * float(sig_k.max(initial=0.0))))
    k_width = 2 * radius + 1
    x = np.arange(-radius, radius + 1, dtype=np.float64)
    delta = (x == 0).astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        g = np.exp(-0.5 * (x / np.where(sig_k == 0, 1.0, sig_k)[..., None]) ** 2)
    bank = np.where((sig_k > 0)[..., None], g, delta)
    bank = bank / bank.sum(axis=-1, keepdims=True)
    assert bank.shape == s.shape + (k_width,)
    return bank.astype(np.float32)


def convolve_separable(image: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """(H, W) image * 1-D kernel applied along both axes (edge-padded)."""
    r = (kernel.shape[0] - 1) // 2

    def conv1d(row):
        return jnp.convolve(jnp.pad(row, (r, r), mode="edge"), kernel, mode="valid")

    out = jax.vmap(conv1d)(image)          # rows
    out = jax.vmap(conv1d)(out.T).T        # cols
    return out


def convolve_batch(images: jnp.ndarray, kernels: jnp.ndarray) -> jnp.ndarray:
    """(N, H, W) images, each convolved with its own per-slot kernel.

    Dispatches on bank rank: (N, K) rows from `matching_kernel_bank` apply
    separably; (N, K, K) taps from `homogenization_bank` apply as full 2-D
    correlations (`convolve_2d`).  A delta row makes the convolution exact
    identity up to float rounding.  K == 1 (a bank with zero max radius,
    i.e. nothing to widen) short-circuits to a multiply.
    """
    if kernels.ndim == images.ndim:  # (N, K, K) measured-PSF bank
        if kernels.shape[-1] == 1:
            return images * kernels[..., 0, 0][:, None, None]
        return jax.vmap(convolve_2d)(images, kernels)
    if kernels.shape[-1] == 1:
        return images * kernels[..., 0][:, None, None]
    return jax.vmap(convolve_separable)(images, kernels)


def match_psf(image: jnp.ndarray, sigma_image: float, sigma_target: float) -> jnp.ndarray:
    """Convolve to the target PSF. No-op if already at/above target width."""
    if sigma_target <= sigma_image:
        return image
    sigma_k = float(np.sqrt(sigma_target**2 - sigma_image**2))
    return convolve_separable(image, gaussian_kernel_1d(sigma_k))


# ----- measured-PSF homogenization (Lupton-style, paper footnote 2) -----

def gaussian_stamp(sigma: float, size: int) -> np.ndarray:
    """(size, size) unit-sum circular Gaussian — the homogenization target."""
    if size % 2 == 0:
        raise ValueError(f"stamp size must be odd, got {size}")
    c = (size - 1) / 2.0
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64)
    g = np.exp(-0.5 * ((xx - c) ** 2 + (yy - c) ** 2) / max(sigma, 1e-6) ** 2)
    return (g / g.sum()).astype(np.float64)


def stamp_sigma(stamps: np.ndarray) -> np.ndarray:
    """Gaussian-equivalent width per stamp from second moments.

    ``stamps`` is (..., S, S); the result is (...,).  The radially averaged
    second moment sqrt(<r^2>/2) equals sigma exactly for a Gaussian and is
    the honest scalar width for anything else (elliptical Moffats included)
    — it is what the monotonicity clamp compares against the target.
    Zero-sum (empty-slot) stamps report width 0.
    """
    s = np.asarray(stamps, np.float64)
    size = s.shape[-1]
    c = (size - 1) / 2.0
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64)
    r2 = (xx - c) ** 2 + (yy - c) ** 2
    tot = s.sum(axis=(-2, -1))
    mom = (s * r2).sum(axis=(-2, -1))
    with np.errstate(divide="ignore", invalid="ignore"):
        sig = np.sqrt(np.maximum(mom / np.where(tot == 0, 1.0, tot), 0.0) / 2.0)
    return np.where(tot > 0, sig, 0.0)


def _delta_stamp(size: int) -> np.ndarray:
    d = np.zeros((size, size), np.float64)
    d[(size - 1) // 2, (size - 1) // 2] = 1.0
    return d


def homogenization_kernel(
    stamp: np.ndarray, target: np.ndarray, ridge: float = 1e-6
) -> np.ndarray:
    """Solve ``stamp * k = target`` for one (S, S) matching kernel.

    Regularized least squares in Fourier space: with hats the (zero-padded,
    linear-convolution-sized) transforms, the minimizer of
    ``||k * stamp - target||^2 + lam ||k||^2`` is
    ``K = conj(S) T / (|S|^2 + lam)`` with ``lam = ridge * max|S|^2`` —
    the ridge bounds the effective deconvolution where the stamp's transform
    runs out of power, which is what keeps measured (noisy-tailed) PSFs from
    amplifying into ringing kernels.  The solve uses the *convolution*
    convention; the returned kernel is flipped so applying it with the
    runtime correlation op (`convolve_2d` / the Pallas banded matmuls)
    realizes the fit.  Unit-sum normalized: matching conserves flux exactly.
    """
    s = np.asarray(stamp, np.float64)
    t = np.asarray(target, np.float64)
    size = s.shape[-1]
    # Odd linear-convolution size: no wraparound inside the crop, and the
    # stamp center sits exactly on the (i)fftshift origin at (n-1)/2.
    n = 2 * size - 1
    s_hat = np.fft.fft2(np.fft.ifftshift(_center_embed(s, n)))
    t_hat = np.fft.fft2(np.fft.ifftshift(_center_embed(t, n)))
    power = np.abs(s_hat) ** 2
    lam = ridge * power.max()
    k_hat = np.conj(s_hat) * t_hat / (power + lam)
    k_full = np.fft.fftshift(np.fft.ifft2(k_hat).real)
    lo = (n - size) // 2
    k = k_full[lo : lo + size, lo : lo + size]
    k = k[::-1, ::-1]  # convolution solve -> correlation-convention taps
    tot = k.sum()
    if abs(tot) < 1e-8:
        return _delta_stamp(size)
    return k / tot


def _center_embed(stamp: np.ndarray, n: int) -> np.ndarray:
    """Place an (S, S) stamp at the center of an (n, n) zero canvas."""
    size = stamp.shape[-1]
    out = np.zeros((n, n), np.float64)
    lo = (n - size) // 2
    out[lo : lo + size, lo : lo + size] = stamp
    return out


def homogenization_bank(
    stamps: np.ndarray,
    psf_sigmas: np.ndarray,
    sigma_target: float,
    ridge: float = 1e-6,
    clamp_tol: float = 1.02,
) -> np.ndarray:
    """Per-slot 2-D matching kernels from measured PSF stamps.

    ``stamps`` is (..., S, S) — any leading slot shape, e.g. the seqfile
    (P, cap) grid — and the result is (..., S, S) float32: one non-separable
    correlation kernel per slot taking that slot's measured PSF to a
    circular Gaussian of ``sigma_target``.  The static tap width S is shared
    across the bank (jit/Pallas operand contract, like `matching_kernel_bank`).

    Empty slots (``psf_sigmas <= 0`` or zero-sum stamps) get exact delta
    rows.  Slots whose *measured* width already exceeds the target get delta
    rows too — matching is monotone, it never deconvolves — and the bank
    warns once with the clamp count so a mis-chosen target is loud rather
    than silently sharpening.
    """
    s = np.asarray(stamps, np.float64)
    if s.shape[-1] != s.shape[-2] or s.shape[-1] % 2 == 0:
        raise ValueError(f"stamps must be odd square, got {s.shape[-2:]}")
    size = s.shape[-1]
    lead = s.shape[:-2]
    sig = np.asarray(psf_sigmas, np.float64).reshape(-1)
    flat = s.reshape((-1, size, size))
    target = gaussian_stamp(sigma_target, size)
    delta = _delta_stamp(size)
    widths = stamp_sigma(flat)
    empty = (sig <= 0) | (flat.sum(axis=(-2, -1)) <= 0)
    too_wide = ~empty & (widths > clamp_tol * float(stamp_sigma(target)))
    out = np.broadcast_to(delta, flat.shape).copy()
    ok = ~(empty | too_wide)
    if ok.any():
        # Batched form of `homogenization_kernel` — same math, one FFT call
        # over all live slots instead of a per-slot Python loop (a layout
        # is P*cap slots; production archives make the loop the bottleneck).
        n = 2 * size - 1
        lo = (n - size) // 2
        emb = np.zeros((int(ok.sum()), n, n), np.float64)
        emb[:, lo : lo + size, lo : lo + size] = flat[ok]
        s_hat = np.fft.fft2(np.fft.ifftshift(emb, axes=(-2, -1)))
        t_hat = np.fft.fft2(np.fft.ifftshift(_center_embed(target, n)))
        power = np.abs(s_hat) ** 2
        lam = ridge * power.max(axis=(-2, -1), keepdims=True)
        k_hat = np.conj(s_hat) * t_hat[None] / (power + lam)
        k_full = np.fft.fftshift(np.fft.ifft2(k_hat).real, axes=(-2, -1))
        k = k_full[:, lo : lo + size, lo : lo + size][:, ::-1, ::-1]
        tot = k.sum(axis=(-2, -1), keepdims=True)
        k = np.where(np.abs(tot) < 1e-8, delta, k / np.where(tot == 0, 1.0, tot))
        out[ok] = k
    if too_wide.any():
        warnings.warn(
            f"homogenization_bank: {int(too_wide.sum())}/{len(flat)} stamps "
            f"wider than target sigma={sigma_target}; clamped to delta "
            "(matching never deconvolves)",
            RuntimeWarning,
            stacklevel=2,
        )
    return out.reshape(lead + (size, size)).astype(np.float32)


def convolve_2d(image: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """(H, W) image correlated with one (K, K) kernel, edge-clamped.

    ``out[i, j] = sum_{m,n} kernel[m, n] * image[clip(i+m-r), clip(j+n-r)]``
    — edge padding makes the clip; `lax.conv_general_dilated` is already a
    cross-correlation, so the taps apply unflipped, exactly like the Pallas
    2-D banded-matmul variant (`warp._convolve_2d_matmul`).
    """
    kh, kw = kernel.shape
    padded = jnp.pad(
        image, (((kh - 1) // 2,) * 2, ((kw - 1) // 2,) * 2), mode="edge"
    )
    out = jax.lax.conv_general_dilated(
        padded[None, None].astype(jnp.float32),
        kernel[None, None].astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
    )
    return out[0, 0]
