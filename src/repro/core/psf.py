"""PSF matching (beyond-paper; the paper deferred it — their footnote 2).

Before stacking, exposures taken in different seeing should be convolved to
a common (worst) PSF so the coadd has a well-defined point-spread function.
We implement the Gaussian-to-Gaussian case: if an image has PSF sigma_i and
the target is sigma_t >= sigma_i, convolving with a Gaussian of
sigma_k = sqrt(sigma_t^2 - sigma_i^2) matches them exactly (Gaussians are
closed under convolution).

Separable implementation (two 1-D convs) — O(H*W*K) and jit/vmap-friendly;
the engine applies it per image in the map stage when
``CoaddEngine(..., match_psf_sigma=...)`` is set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gaussian_kernel_1d(sigma: float, radius: int | None = None) -> jnp.ndarray:
    if sigma <= 0:
        return jnp.ones((1,), jnp.float32)
    radius = radius or max(1, int(np.ceil(3.0 * sigma)))
    x = jnp.arange(-radius, radius + 1, dtype=jnp.float32)
    k = jnp.exp(-0.5 * (x / sigma) ** 2)
    return k / k.sum()


def convolve_separable(image: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """(H, W) image * 1-D kernel applied along both axes (edge-padded)."""
    r = (kernel.shape[0] - 1) // 2

    def conv1d(row):
        return jnp.convolve(jnp.pad(row, (r, r), mode="edge"), kernel, mode="valid")

    out = jax.vmap(conv1d)(image)          # rows
    out = jax.vmap(conv1d)(out.T).T        # cols
    return out


def match_psf(image: jnp.ndarray, sigma_image: float, sigma_target: float) -> jnp.ndarray:
    """Convolve to the target PSF. No-op if already at/above target width."""
    if sigma_target <= sigma_image:
        return image
    sigma_k = float(np.sqrt(sigma_target**2 - sigma_image**2))
    return convolve_separable(image, gaussian_kernel_1d(sigma_k))
