"""Reduce stage: accumulate projected tiles into coadd + depth.

Faithful to Algorithm 3: sum projected illumination into `coadd` and
coverage into `depth`.  The accumulation is a commutative monoid, which is
exactly why the paper could run one serial reducer per query — and why we
may replace Hadoop's shuffle+serial-reduce with an O(log N) collective tree:
`jax.lax.psum_scatter` over the `data` axis leaves the coadd sharded by
output tile over the `model` axis (reducer parallelism = paper's "parallel
over queries", plus tile parallelism the paper's single reducer lacked).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def reduce_local(tiles: jnp.ndarray, covs: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Serial (per-device) accumulation over the image axis."""
    return tiles.sum(axis=0), covs.sum(axis=0)


def normalize(coadd: jnp.ndarray, depth: jnp.ndarray) -> jnp.ndarray:
    """Depth-normalized stack (mean image); zero where depth == 0."""
    return jnp.where(depth > 0, coadd / jnp.maximum(depth, 1e-6), 0.0)


def mosaic_tiles(
    tiles: jnp.ndarray,
    covs: jnp.ndarray,
    offsets: jnp.ndarray,
    npix: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted-sum merge of brick tiles into an (npix, npix) mosaic.

    ``tiles``/``covs`` are (B, bh, bw) cached brick coadds + weight maps,
    ``offsets`` (B, 2) int32 (row, col) output positions.  Accumulation into
    a zero canvas is the same reduce monoid as `reduce_local` — bricks never
    overlap, so add == write, but accumulating keeps the merge commutative
    and bitwise-matches the Pallas variant (`kernels.warp.mosaic_bricks`).
    """
    coadd = jnp.zeros((npix, npix), tiles.dtype)
    depth = jnp.zeros((npix, npix), covs.dtype)

    def body(carry, op):
        co, de = carry
        tile, cov, off = op
        r, c = off[0], off[1]
        patch = jax.lax.dynamic_slice(co, (r, c), tile.shape) + tile
        co = jax.lax.dynamic_update_slice(co, patch, (r, c))
        dpatch = jax.lax.dynamic_slice(de, (r, c), cov.shape) + cov
        de = jax.lax.dynamic_update_slice(de, dpatch, (r, c))
        return (co, de), None

    (coadd, depth), _ = jax.lax.scan(body, (coadd, depth), (tiles, covs, offsets))
    return coadd, depth


def reduce_collective(
    local_coadd: jnp.ndarray,
    local_depth: jnp.ndarray,
    axis_name: str = "data",
    scatter_axis_name: str | None = "model",
):
    """Cross-device reduction of per-device partial coadds.

    Inside `shard_map`: psum over the data axis; when a model axis exists the
    result is immediately reduce-scattered over output rows so each model
    shard owns a horizontal band of the coadd (distributed reducer).
    """
    # psum one axis at a time (tuple axis names trip a jax-0.8 shard_map
    # invariant check); sequential psums lower to the same collectives.
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    coadd, depth = local_coadd, local_depth
    for ax in axes:
        coadd = jax.lax.psum(coadd, ax)
        depth = jax.lax.psum(depth, ax)
    if scatter_axis_name is None:
        return coadd, depth
    # Images are sharded over data AND model axes; finish the reduction over
    # the model axis with a reduce-scatter so each model shard ends up owning
    # a horizontal band of the (fully reduced) coadd.  Requires npix % model
    # == 0; the engine sizes query grids accordingly.
    coadd = jax.lax.psum_scatter(coadd, scatter_axis_name, scatter_dimension=0, tiled=True)
    depth = jax.lax.psum_scatter(depth, scatter_axis_name, scatter_dimension=0, tiled=True)
    return coadd, depth
