"""Reduce stage: accumulate projected tiles into coadd + depth.

Faithful to Algorithm 3: sum projected illumination into `coadd` and
coverage into `depth`.  The accumulation is a commutative monoid, which is
exactly why the paper could run one serial reducer per query — and why we
may replace Hadoop's shuffle+serial-reduce with an O(log N) collective tree:
`jax.lax.psum_scatter` over the `data` axis leaves the coadd sharded by
output tile over the `model` axis (reducer parallelism = paper's "parallel
over queries", plus tile parallelism the paper's single reducer lacked).

Robust stacks (DESIGN.md §11) are *not* monoids — a sigma-clipped mean
needs every sample's distance from a center that only exists once all
samples have been seen.  They decompose into monoidal scans, though: pass 1
accumulates weighted moments (S0, S1, S2), which fix the clip center and
radius (and, for the two-round median+clip a la tractor's unwise-coadd, a
binapprox histogram whose bins the moments bound); pass 2 re-scans with the
center/radius as plain fixed operands and accumulates only surviving
samples.  Every per-pass partial here is an elementwise sum over the image
axis, so the streaming window machinery, journals, and kill-and-resume all
keep working unchanged — they just run more passes.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

#: Reduction variants every executor understands (engine `reduce=` values).
REDUCERS = ("mean", "clipped", "median")

# Clip-radius noise guard.  The streaming moments give variance by the
# single-pass form S2/S0 - mu^2, whose float32 cancellation error scales as
# sqrt(eps)*|mu| ~ 3.5e-4*|mu| — on a near-constant stack the computed sigma
# is noise at that scale (possibly exactly 0 while samples sit 1 ulp off the
# mean), and an unguarded k*sigma radius would clip *every* sample and zero
# the stack, with different engines flipping different pixels.  The relative
# term absorbs that: samples within 1e-3 of the center are never outliers.
_CLIP_REL = 1e-3
_CLIP_ABS = 1e-12


def reduce_local(tiles: jnp.ndarray, covs: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Serial (per-device) accumulation over the image axis."""
    return tiles.sum(axis=0), covs.sum(axis=0)


def normalize(coadd: jnp.ndarray, depth: jnp.ndarray) -> jnp.ndarray:
    """Depth-normalized stack (mean image); zero where depth == 0.

    Exact masking, no epsilon clamp: clip masks make fractional depths
    (a 0.5-coverage border pixel) routine, and ``max(depth, 1e-6)`` would
    silently rescale them instead of dividing by the true weight.
    """
    return jnp.where(depth > 0, coadd / jnp.where(depth > 0, depth, 1.0), 0.0)


# ----- robust stacks: monoidal passes (DESIGN.md §11) -----------------------

def _samples(tiles: jnp.ndarray, covs: jnp.ndarray) -> jnp.ndarray:
    """Per-image sample values x_i = t_i / c_i (0 where uncovered)."""
    return jnp.where(covs > 0, tiles / jnp.where(covs > 0, covs, 1.0), 0.0)


def moments_local(
    tiles: jnp.ndarray, covs: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pass-1 monoid: coverage-weighted moments over the image axis.

    With weight c_i and sample x_i = t_i/c_i per contributing image:
    S0 = Σ c_i, S1 = Σ c_i x_i = Σ t_i, S2 = Σ c_i x_i² = Σ t_i²/c_i.
    All three are plain sums — journal/resume-safe exactly like the mean.
    """
    x = _samples(tiles, covs)
    return covs.sum(axis=0), tiles.sum(axis=0), (x * tiles).sum(axis=0)


def clip_stats(
    s0: jnp.ndarray, s1: jnp.ndarray, s2: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(mean, sigma) per pixel from moment partials; zeros where S0 == 0."""
    safe = jnp.where(s0 > 0, s0, 1.0)
    mu = jnp.where(s0 > 0, s1 / safe, 0.0)
    var = jnp.maximum(jnp.where(s0 > 0, s2 / safe, 0.0) - mu * mu, 0.0)
    return mu, jnp.sqrt(var)


def clip_threshold(center: jnp.ndarray, sigma: jnp.ndarray, k: float) -> jnp.ndarray:
    """k-sigma clip radius with the ulp guard (see _CLIP_REL/_CLIP_ABS)."""
    return k * sigma + _CLIP_REL * jnp.abs(center) + _CLIP_ABS


def clip_local(
    tiles: jnp.ndarray,
    covs: jnp.ndarray,
    center: jnp.ndarray,
    thresh: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pass-2 monoid: accumulate only samples inside the clip window.

    ``center``/``thresh`` are *fixed operands* computed from the completed
    pass-1 moments — this pass is again a plain sum, so window partials
    remain additive and resumable.

    The test is the division-free form |t - c*center| <= c*thresh (both
    sides of |t/c - center| <= thresh scaled by the nonnegative coverage):
    exact in the reals, ~2.5x cheaper than a per-sample divide on the hot
    clip sweep, and — since every path (XLA, streaming windows, Pallas
    `coadd_clip`) tests the same form — one agreed rounding for the clip
    decision, which is what the bitwise depth-parity contract rides on.
    """
    keep = (covs > 0) & (jnp.abs(tiles - covs * center) <= covs * thresh)
    return (
        jnp.where(keep, tiles, 0.0).sum(axis=0),
        jnp.where(keep, covs, 0.0).sum(axis=0),
    )


def hist_bounds(
    s0: jnp.ndarray, s1: jnp.ndarray, s2: jnp.ndarray, nbins: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Binapprox bin bounds (lo, w, inv_w) from the moments.

    lo = mu - sigma, w = 2 sigma / nbins: valid because |mean - median|
    <= sigma for any distribution (Mallows), so the median always lands in
    [lo, lo + 2 sigma].  ``inv_w`` clamps only the *reciprocal* — the real
    w stays exact so a sigma=0 stack reports med = lo = mu exactly.
    """
    mu, sigma = clip_stats(s0, s1, s2)
    w = (2.0 * sigma) / nbins
    return mu - sigma, w, 1.0 / jnp.maximum(w, 1e-30)


def hist_local(
    tiles: jnp.ndarray,
    covs: jnp.ndarray,
    lo: jnp.ndarray,
    inv_w: jnp.ndarray,
    nbins: int,
) -> jnp.ndarray:
    """Median round-1 monoid: coverage-weighted binapprox histogram.

    Returns (nbins, H, W); ``lo``/``inv_w`` are fixed operands from the
    completed moments pass, so this too is a plain elementwise sum.

    One fused compare+select+reduce sweep per bin rather than a broadcast
    against a (nbins, N, H, W) onehot: the per-bin sums (and their order)
    are identical, but nothing nbins times the stack size ever
    materializes, which matters once the resident robust path feeds the
    whole gated stack through here in one call.  The bin sweep runs as a
    `lax.scan` over the bin axis so the int8 bin indices and weights are
    loop-invariant operands XLA must pin to memory once — an unrolled
    python loop lets it fuse the sample division back into every one of
    the nbins sweeps instead, which measures ~2.4x slower.  The per-bin
    sums (and their order) are unchanged bit for bit.
    """
    x = _samples(tiles, covs)
    b = jnp.clip(jnp.floor((x - lo) * inv_w), 0, nbins - 1).astype(jnp.int8)
    cw = jnp.where(covs > 0, covs, 0.0)

    def _bin(carry, j):
        return carry, jnp.where(b == j, cw, 0.0).sum(axis=0)

    _, hist = jax.lax.scan(_bin, 0, jnp.arange(nbins, dtype=jnp.int8))
    return hist


def hist_median(
    hist: jnp.ndarray, s0: jnp.ndarray, lo: jnp.ndarray, w: jnp.ndarray
) -> jnp.ndarray:
    """Approximate weighted median: first bin whose cumsum crosses S0/2."""
    c = jnp.cumsum(hist, axis=0)
    j = jnp.argmax(c >= 0.5 * s0[None], axis=0).astype(hist.dtype)
    return lo + (j + 0.5) * w


def robust_local(
    tiles: jnp.ndarray,
    covs: jnp.ndarray,
    reduce: str = "clipped",
    clip_k: float = 3.0,
    median_bins: int = 16,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-shot robust stack of an in-memory (N, H, W) sample stack.

    The eager composition of the streaming passes: moments -> (binapprox
    histogram for "median") -> clip re-scan, with identical operand math to
    the multi-pass streaming contract (DESIGN.md §11) — fusing only removes
    the host round-trips between passes.
    """
    s0, s1, s2 = moments_local(tiles, covs)
    mu, sigma = clip_stats(s0, s1, s2)
    if reduce == "median":
        lo, w, inv_w = hist_bounds(s0, s1, s2, median_bins)
        center = hist_median(
            hist_local(tiles, covs, lo, inv_w, median_bins), s0, lo, w
        )
    elif reduce == "clipped":
        center = mu
    else:
        raise ValueError(f"robust_local: unknown reduce {reduce!r}")
    return clip_local(tiles, covs, center, clip_threshold(center, sigma, clip_k))


def mosaic_tiles(
    tiles: jnp.ndarray,
    covs: jnp.ndarray,
    offsets: jnp.ndarray,
    npix: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted-sum merge of brick tiles into an (npix, npix) mosaic.

    ``tiles``/``covs`` are (B, bh, bw) cached brick coadds + weight maps,
    ``offsets`` (B, 2) int32 (row, col) output positions.  Accumulation into
    a zero canvas is the same reduce monoid as `reduce_local` — bricks never
    overlap, so add == write, but accumulating keeps the merge commutative
    and bitwise-matches the Pallas variant (`kernels.warp.mosaic_bricks`).
    """
    coadd = jnp.zeros((npix, npix), tiles.dtype)
    depth = jnp.zeros((npix, npix), covs.dtype)

    def body(carry, op):
        co, de = carry
        tile, cov, off = op
        r, c = off[0], off[1]
        patch = jax.lax.dynamic_slice(co, (r, c), tile.shape) + tile
        co = jax.lax.dynamic_update_slice(co, patch, (r, c))
        dpatch = jax.lax.dynamic_slice(de, (r, c), cov.shape) + cov
        de = jax.lax.dynamic_update_slice(de, dpatch, (r, c))
        return (co, de), None

    (coadd, depth), _ = jax.lax.scan(body, (coadd, depth), (tiles, covs, offsets))
    return coadd, depth


def reduce_collective(
    local_coadd: jnp.ndarray,
    local_depth: jnp.ndarray,
    axis_name: str = "data",
    scatter_axis_name: str | None = "model",
):
    """Cross-device reduction of per-device partial coadds.

    Inside `shard_map`: psum over the data axis; when a model axis exists the
    result is immediately reduce-scattered over output rows so each model
    shard owns a horizontal band of the coadd (distributed reducer).
    """
    # psum one axis at a time (tuple axis names trip a jax-0.8 shard_map
    # invariant check); sequential psums lower to the same collectives.
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    coadd, depth = local_coadd, local_depth
    for ax in axes:
        coadd = jax.lax.psum(coadd, ax)
        depth = jax.lax.psum(depth, ax)
    if scatter_axis_name is None:
        return coadd, depth
    # Images are sharded over data AND model axes; finish the reduction over
    # the model axis with a reduce-scatter so each model shard ends up owning
    # a horizontal band of the (fully reduced) coadd.  Requires npix % model
    # == 0; the engine sizes query grids accordingly.
    coadd = jax.lax.psum_scatter(coadd, scatter_axis_name, scatter_dimension=0, tiled=True)
    depth = jax.lax.psum_scatter(depth, scatter_axis_name, scatter_dimension=0, tiled=True)
    return coadd, depth
