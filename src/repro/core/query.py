"""Coadd queries.

A query (paper §2.1, Algorithm 1) selects a bandpass filter and an RA/Dec
bounding box, and defines the common output coordinate system the accepted
images are projected onto.  We additionally support the paper's proposed
time-bounds extension (§6, future work) as an optional [t0, t1] window.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.geometry import WCS, make_grid_wcs

BANDS = ("u", "g", "r", "i", "z")
BAND_INDEX = {b: i for i, b in enumerate(BANDS)}


@dataclasses.dataclass(frozen=True)
class CoaddQuery:
    """One coaddition request.

    Attributes:
      band: bandpass name, one of ``BANDS``.
      ra_bounds / dec_bounds: query sky box in degrees.
      npix: output grid is ``npix x npix``.
      time_bounds: optional (t0, t1) observation-time window (paper §6).
    """

    band: str
    ra_bounds: Tuple[float, float]
    dec_bounds: Tuple[float, float]
    npix: int = 128
    time_bounds: Optional[Tuple[float, float]] = None

    @property
    def band_id(self) -> int:
        return BAND_INDEX[self.band]

    @property
    def bounds(self) -> Tuple[float, float, float, float]:
        return (
            self.ra_bounds[0],
            self.ra_bounds[1],
            self.dec_bounds[0],
            self.dec_bounds[1],
        )

    @property
    def center(self) -> Tuple[float, float]:
        return (
            0.5 * (self.ra_bounds[0] + self.ra_bounds[1]),
            0.5 * (self.dec_bounds[0] + self.dec_bounds[1]),
        )

    @property
    def fov_deg(self) -> float:
        return max(
            self.ra_bounds[1] - self.ra_bounds[0],
            self.dec_bounds[1] - self.dec_bounds[0],
        )

    def grid_wcs(self) -> WCS:
        ra_c, dec_c = self.center
        return make_grid_wcs(ra_c, dec_c, self.npix, self.fov_deg)

    def grid_wcs_vector(self) -> np.ndarray:
        return self.grid_wcs().to_vector()

    def time_window(self) -> Tuple[float, float]:
        if self.time_bounds is None:
            return (-np.inf, np.inf)
        return self.time_bounds
