"""Core: the paper's contribution — MapReduce image coaddition in JAX.

Public API:
  CoaddQuery, make_survey, SurveyConfig, CoaddEngine, METHODS,
  SpatialIndex, JobTracker, WindowTracker, ChaosInjector,
  CoaddService, Overloaded, ServiceStats.
"""

from repro.core.bricks import BrickCover, BrickGrid
from repro.core.detect import (
    DetectionCatalog,
    detect_sources,
    difference_image,
    epoch_time_bounds,
    inject_transients,
    match_detections,
)
from repro.core.durable import BrickSpill, DiskJournal, JournalStore
from repro.core.engine import METHODS, CoaddEngine, CoaddResult, JobStats
from repro.core.faults import (
    ChaosInjector,
    DeterminismError,
    FatalFault,
    FaultError,
    FaultSchedule,
    PoisonSpec,
    PoisonedChunkError,
    QueryKilled,
    TransientFault,
    classify,
)
from repro.core.jobtracker import (
    BrickTask,
    FailureInjector,
    FaultCounters,
    JobTracker,
    MapTask,
    MaterializeReport,
    WindowTracker,
)
from repro.core.plan import (
    CoaddPlan,
    ScanWindow,
    SparseScanIndex,
    scan_budget,
    sparse_pack_index,
    stack_plans,
    window_schedule,
)
from repro.core.seqfile import BrickMeta, BrickStore, ResidencyManager
from repro.core.prefilter import SpatialIndex
from repro.core.query import BANDS, CoaddQuery
from repro.core.serve import CoaddService, Overloaded, ServiceStats
from repro.core.survey import Survey, SurveyConfig, make_survey

__all__ = [
    "BANDS",
    "BrickCover",
    "BrickGrid",
    "BrickMeta",
    "BrickSpill",
    "BrickStore",
    "BrickTask",
    "ChaosInjector",
    "DiskJournal",
    "CoaddEngine",
    "CoaddPlan",
    "CoaddResult",
    "CoaddQuery",
    "CoaddService",
    "DetectionCatalog",
    "DeterminismError",
    "FailureInjector",
    "FatalFault",
    "FaultCounters",
    "FaultError",
    "FaultSchedule",
    "JobStats",
    "JobTracker",
    "JournalStore",
    "MapTask",
    "MaterializeReport",
    "METHODS",
    "Overloaded",
    "PoisonSpec",
    "PoisonedChunkError",
    "QueryKilled",
    "ResidencyManager",
    "ScanWindow",
    "ServiceStats",
    "SparseScanIndex",
    "SpatialIndex",
    "Survey",
    "SurveyConfig",
    "TransientFault",
    "WindowTracker",
    "classify",
    "detect_sources",
    "difference_image",
    "epoch_time_bounds",
    "inject_transients",
    "make_survey",
    "match_detections",
    "scan_budget",
    "sparse_pack_index",
    "stack_plans",
    "window_schedule",
]
