"""Core: the paper's contribution — MapReduce image coaddition in JAX.

Public API:
  CoaddQuery, make_survey, SurveyConfig, CoaddEngine, METHODS,
  SpatialIndex, JobTracker.
"""

from repro.core.engine import METHODS, CoaddEngine, CoaddResult, JobStats
from repro.core.jobtracker import FailureInjector, JobTracker, MapTask
from repro.core.plan import (
    CoaddPlan,
    ScanWindow,
    SparseScanIndex,
    scan_budget,
    sparse_pack_index,
    stack_plans,
    window_schedule,
)
from repro.core.seqfile import ResidencyManager
from repro.core.prefilter import SpatialIndex
from repro.core.query import BANDS, CoaddQuery
from repro.core.survey import Survey, SurveyConfig, make_survey

__all__ = [
    "BANDS",
    "CoaddEngine",
    "CoaddPlan",
    "CoaddResult",
    "CoaddQuery",
    "FailureInjector",
    "JobStats",
    "JobTracker",
    "MapTask",
    "METHODS",
    "ResidencyManager",
    "ScanWindow",
    "SparseScanIndex",
    "SpatialIndex",
    "Survey",
    "SurveyConfig",
    "make_survey",
    "scan_budget",
    "sparse_pack_index",
    "stack_plans",
    "window_schedule",
]
