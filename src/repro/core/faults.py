"""Fault taxonomy + deterministic chaos injection (DESIGN.md §8).

The paper's scaling premise (§3) is that at cluster scale *failures are the
norm* — the framework's job is to hide transient faults (re-execute), route
around stragglers (speculate), and contain bad inputs (quarantine) without
changing the answer.  This module supplies the two host-side halves of that
contract:

* a small **fault taxonomy** (`classify`) shared by the legacy `JobTracker`
  and the streaming `WindowTracker`: transient errors are retried with
  capped exponential backoff, fatal errors escape immediately.  The split is
  deliberate policy, not exception pedigree — XLA surfaces device/transfer
  failures as bare ``RuntimeError``, so that type is transient by default,
  while `DeterminismError` (two executions of one task disagreeing) must
  never be retried: re-running nondeterminism just rolls the dice again.

* a **chaos harness** (`FaultSchedule` + `ChaosInjector`) that injects
  failures at the engine's *real* seams — `ResidencyManager` chunk uploads,
  staged chunk pixels, window dispatch wall-clock, mid-query kills — by
  deterministic ordinal, so every drill is reproducible and the recovered
  result can be asserted bitwise against the fault-free run.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Iterable, Optional, Tuple

import numpy as np


# ----- fault taxonomy -----
class FaultError(Exception):
    """Base of the engine's own fault types (injected or detected)."""


class TransientFault(FaultError):
    """A retryable failure: lost upload RPC, flaky transfer, worker loss."""


class FatalFault(FaultError):
    """A failure retrying cannot fix; escapes every retry net."""


class DeterminismError(FatalFault):
    """Two executions of one idempotent task produced different digests."""


class QueryKilled(FatalFault):
    """Injected mid-query kill: the query dies, its journal survives."""


class PoisonedChunkError(FaultError):
    """Staged chunk pixels failed verification (NaN/Inf or digest mismatch).

    Carries the *global* (execution-layout) pack indices that failed, so the
    quarantine policy can gate exactly those packs out and report them as
    ``uncovered_packs``.
    """

    def __init__(self, packs: Iterable[int], reason: str = "verification failed"):
        self.packs = tuple(sorted(int(p) for p in packs))
        super().__init__(f"poisoned packs {self.packs}: {reason}")


# RuntimeError is transient by policy: XLA reports device/transfer errors as
# RuntimeError, and so does the legacy FailureInjector.  FatalFault subclasses
# (DeterminismError, QueryKilled) are checked first and always escape.
_TRANSIENT_TYPES = (
    TransientFault,
    ConnectionError,
    TimeoutError,
    InterruptedError,
    OSError,
    RuntimeError,
)


def classify(exc: BaseException) -> str:
    """``"transient"`` (retry) or ``"fatal"`` (escape) for an exception.

    `PoisonedChunkError` classifies transient — a corrupted transfer heals on
    re-upload — but the `WindowTracker` intercepts it *before* classification
    so persistent poison can escalate to quarantine instead of exhausting
    retries.
    """
    if isinstance(exc, FatalFault):
        return "fatal"
    if isinstance(exc, (PoisonedChunkError,) + _TRANSIENT_TYPES):
        return "transient"
    return "fatal"


# ----- deterministic chaos schedule -----
@dataclasses.dataclass
class PoisonSpec:
    """Corrupt one pack's staged pixels for ``count`` chunk builds.

    ``count=None`` poisons every build (persistent bad input — the quarantine
    case); a finite count models transfer corruption that heals on retry.
    ``mode="flip"`` corrupts with *finite* values, which only the digest
    check catches (``CoaddEngine(verify_digests=True)``) — the NaN/Inf scan
    is blind to it by design.
    """

    pack: int
    mode: str = "nan"            # "nan" | "inf" | "flip"
    count: Optional[int] = 1


@dataclasses.dataclass
class FaultSchedule:
    """A reproducible failure plan, addressed by deterministic ordinals.

    * ``upload_fail_ordinals`` — fail the k-th chunk-build attempt (counted
      across the whole engine lifetime) with a `TransientFault`: the upload
      RPC that never arrived.
    * ``poison`` — corrupt staged pixels of specific packs (`PoisonSpec`).
    * ``slow_windows`` — sleep inside the k-th window execution: a straggler.
    * ``kill_after_windows`` — raise `QueryKilled` once N windows have
      completed (after journaling, so resume has something to replay).
    """

    upload_fail_ordinals: Tuple[int, ...] = ()
    poison: Tuple[PoisonSpec, ...] = ()
    slow_windows: Dict[int, float] = dataclasses.field(default_factory=dict)
    kill_after_windows: Optional[int] = None

    @classmethod
    def seeded(
        cls,
        seed: int,
        n_uploads: int,
        n_windows: int,
        gated_packs: np.ndarray,
        upload_fails: int = 1,
        poisons: int = 1,
        stragglers: int = 1,
        slow_s: float = 0.05,
    ) -> "FaultSchedule":
        """Draw a schedule from a seed (the CI chaos-smoke drill generator).

        The caller supplies the query's shape — how many chunk builds and
        windows a clean run performs, and which packs its gate opens — so
        every drawn fault lands on a seam the query actually crosses.
        """
        rng = np.random.default_rng(seed)
        pool = np.asarray(gated_packs, np.int64)
        ordinals = tuple(
            sorted(
                int(o)
                for o in rng.choice(
                    max(n_uploads, 1),
                    size=min(upload_fails, max(n_uploads, 1)),
                    replace=False,
                )
            )
        )
        specs = tuple(
            PoisonSpec(pack=int(p), mode="nan", count=1)
            for p in rng.choice(pool, size=min(poisons, len(pool)), replace=False)
        )
        # Stragglers only speculate once a duration median exists, so draw
        # slow ordinals past the first window.
        lo = min(1, max(n_windows - 1, 0))
        slow = {
            int(o): slow_s
            for o in rng.choice(
                np.arange(lo, max(n_windows, lo + 1)),
                size=min(stragglers, max(n_windows - lo, 1)),
                replace=False,
            )
        }
        return cls(ordinals, specs, slow, None)


class ChaosInjector:
    """Replays a `FaultSchedule` against the engine's real seams.

    One injector = one deterministic drill: it keeps its own ordinal
    counters (upload attempts seen, windows executed, windows completed) and
    an ``injected`` Counter the tests assert against, so a drill proves its
    faults actually fired rather than silently missing every seam.
    """

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self.upload_attempts = 0
        self.window_execs = 0
        self.windows_completed = 0
        self.injected: "collections.Counter[str]" = collections.Counter()
        self._fail_ordinals = frozenset(schedule.upload_fail_ordinals)
        self._poison_left = {
            i: spec.count for i, spec in enumerate(schedule.poison)
        }
        self._kill_armed = schedule.kill_after_windows is not None

    # seam: ResidencyManager.fault_hook, called on every chunk-build miss
    def on_upload(self, key) -> None:
        ordinal = self.upload_attempts
        self.upload_attempts += 1
        if ordinal in self._fail_ordinals:
            self.injected["upload_fail"] += 1
            raise TransientFault(
                f"injected upload failure (build ordinal {ordinal}, key={key})"
            )

    # seam: staged chunk pixels, before verification
    def corrupt_chunk(
        self, start: int, stop: int, pixels: np.ndarray
    ) -> np.ndarray:
        """Return ``pixels`` with scheduled corruption applied (on a copy —
        the host seqfile stays clean, which is what makes retry heal)."""
        out = None
        for i, spec in enumerate(self.schedule.poison):
            if not start <= spec.pack < stop:
                continue
            left = self._poison_left[i]
            if left is not None and left <= 0:
                continue
            if out is None:
                out = np.array(pixels, copy=True)
            row = out[spec.pack - start]
            if spec.mode == "nan":
                row.reshape(-1)[0] = np.nan
            elif spec.mode == "inf":
                row.reshape(-1)[0] = np.inf
            elif spec.mode == "flip":
                row += 1.0
            else:
                raise ValueError(f"unknown poison mode {spec.mode!r}")
            if left is not None:
                self._poison_left[i] = left - 1
            self.injected["poison"] += 1
        return pixels if out is None else out

    # seam: window execution (inside the tracker's timed region)
    def on_window_execute(self, win) -> None:
        ordinal = self.window_execs
        self.window_execs += 1
        slow_s = self.schedule.slow_windows.get(ordinal)
        if slow_s:
            self.injected["slow"] += 1
            time.sleep(slow_s)

    # seam: window completion (after the partial is journaled)
    def on_window_complete(self, win) -> None:
        self.windows_completed += 1
        if (
            self._kill_armed
            and self.windows_completed >= self.schedule.kill_after_windows
        ):
            # Fire once: the resumed query must replay, not die again.
            self._kill_armed = False
            self.injected["kill"] += 1
            raise QueryKilled(
                f"injected kill after {self.windows_completed} windows"
            )


__all__ = [
    "ChaosInjector",
    "DeterminismError",
    "FatalFault",
    "FaultError",
    "FaultSchedule",
    "PoisonSpec",
    "PoisonedChunkError",
    "QueryKilled",
    "TransientFault",
    "classify",
]
