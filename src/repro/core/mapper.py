"""Map stage: per-image filter + projection onto the query grid.

Faithful to Algorithm 2: the mapper receives one image, checks bandpass and
bounds overlap, and — when accepted — projects ("Astrometry/interpolation")
the image onto the query's common coordinate system, emitting a projected
tile plus its coverage footprint.  Rejected images emit zeros, which is how
a masked SPMD program "discards" a false positive (paper Fig. 6): the
arithmetic cost of discarding is one multiply, matching the paper's
observation that mapper-side filtering is cheap (§4.1.4).

The projection is an *inverse* warp: for every output pixel we compute its
sky position once per query, then per image map sky -> source pixel via the
image's TAN WCS and bilinearly interpolate.  Inverse warping avoids
scatter — every output pixel is a gather, which is the TPU-friendly
formulation (scatters serialize; gathers vectorize) and the basis of the
Pallas kernel in `repro.kernels.warp`.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import pixel_to_sky, sky_to_pixel
from repro.core.query import CoaddQuery


def query_grid_sky(query: CoaddQuery) -> Tuple[np.ndarray, np.ndarray]:
    """Sky coordinates (ra, dec), each (npix, npix), of the output grid.

    Depends only on the query — computed once per job on the host.
    """
    n = query.npix
    g = query.grid_wcs_vector().astype(np.float64)
    xs, ys = np.meshgrid(np.arange(n, dtype=np.float64), np.arange(n, dtype=np.float64))
    ra, dec = pixel_to_sky(xs, ys, g)
    return ra.astype(np.float32), dec.astype(np.float32)


def bilinear_sample(image: jnp.ndarray, sx: jnp.ndarray, sy: jnp.ndarray):
    """Bilinear interpolation of `image` at float coords (sx, sy).

    Returns (values, inside_mask).  Out-of-bounds samples return 0 with
    mask 0 — the coverage map counts only true source pixels.
    """
    h, w = image.shape
    x0 = jnp.floor(sx)
    y0 = jnp.floor(sy)
    dx = sx - x0
    dy = sy - y0
    x0i = x0.astype(jnp.int32)
    y0i = y0.astype(jnp.int32)

    inside = (sx >= 0.0) & (sx <= w - 1.0) & (sy >= 0.0) & (sy <= h - 1.0)

    x0c = jnp.clip(x0i, 0, w - 1)
    x1c = jnp.clip(x0i + 1, 0, w - 1)
    y0c = jnp.clip(y0i, 0, h - 1)
    y1c = jnp.clip(y0i + 1, 0, h - 1)

    v00 = image[y0c, x0c]
    v01 = image[y0c, x1c]
    v10 = image[y1c, x0c]
    v11 = image[y1c, x1c]
    val = (
        v00 * (1 - dx) * (1 - dy)
        + v01 * dx * (1 - dy)
        + v10 * (1 - dx) * dy
        + v11 * dx * dy
    )
    m = inside.astype(image.dtype)
    return val * m, m


def project_one(
    pixels: jnp.ndarray,       # (H, W)
    wcs_vec: jnp.ndarray,      # (8,)
    accept: jnp.ndarray,       # scalar bool/float: band+bounds+time+valid gate
    grid_ra: jnp.ndarray,      # (Q, Q)
    grid_dec: jnp.ndarray,     # (Q, Q)
):
    """Project one image onto the query grid. Returns (tile, coverage)."""
    sx, sy = sky_to_pixel(grid_ra, grid_dec, wcs_vec)
    val, cov = bilinear_sample(pixels, sx, sy)
    a = accept.astype(pixels.dtype)
    return val * a, cov * a


def acceptance_mask(
    band_id: jnp.ndarray,
    valid: jnp.ndarray,
    t_obs: jnp.ndarray,
    ra_min: jnp.ndarray,
    ra_max: jnp.ndarray,
    dec_min: jnp.ndarray,
    dec_max: jnp.ndarray,
    query: CoaddQuery,
) -> jnp.ndarray:
    """Vectorized Algorithm-2 acceptance test over a batch of images."""
    ra0, ra1 = query.ra_bounds
    dec0, dec1 = query.dec_bounds
    t0, t1 = query.time_window()
    ok = (
        (band_id == query.band_id)
        & valid
        & (ra_max >= ra0)
        & (ra_min <= ra1)
        & (dec_max >= dec0)
        & (dec_min <= dec1)
        & (t_obs >= t0)
        & (t_obs <= t1)
    )
    return ok


def gather_packs(
    pack_idx: jnp.ndarray,   # scalar (or (G,)) int32 pack index/indices
    pixels: jnp.ndarray,     # (P, cap, H, W) resident
    wcs_vecs: jnp.ndarray,   # (P, cap, 8)
    ints: dict,              # (P, cap) int32 columns
    floats: dict,            # (P, cap) float32 columns
    psf_kernels: jnp.ndarray | None = None,  # (P, cap, K) / (P, cap, K, K)
):
    """Gather gated pack(s) out of the resident arrays along the pack axis.

    The device half of sparse execution (DESIGN.md §5): the planner derives
    which packs a gate opens (`plan.sparse_pack_index`), and this `jnp.take`
    pulls them from the resident (P, cap, ...) arrays *inside* the jitted
    program — the scan then visits G packs instead of P, so map cost scales
    with selectivity while the dispatch count stays 1.  The engine calls it
    per scan step with a scalar traced index (a dynamic slice of one pack),
    which streams the gather through the scan instead of materializing a
    (G, cap, ...) compacted copy next to the resident layout.  Padding
    entries duplicate pack 0; the compacted gate masks their slots False,
    so they contribute exact zeros like any masked discard.
    """
    take = lambda a: jnp.take(a, pack_idx, axis=0)  # noqa: E731
    return (
        take(pixels),
        take(wcs_vecs),
        {k: take(v) for k, v in ints.items()},
        {k: take(v) for k, v in floats.items()},
        None if psf_kernels is None else take(psf_kernels),
    )


def map_batch(
    pixels: jnp.ndarray,     # (N, H, W)
    wcs_vecs: jnp.ndarray,   # (N, 8)
    accept: jnp.ndarray,     # (N,)
    grid_ra: jnp.ndarray,
    grid_dec: jnp.ndarray,
    use_kernel: bool = False,
    block_rows: int | None = None,
    interpret: bool = True,
    psf_kernels: jnp.ndarray | None = None,  # (N, K) separable rows or
                                             # (N, K, K) measured-PSF taps
):
    """vmapped map stage over a batch of images -> (tiles, coverages).

    When ``psf_kernels`` is given, each image is first convolved to the
    engine's common target PSF — the PSF-matching step the paper deferred,
    inserted before warping so the projected tiles all share one
    point-spread function.  `psf.convolve_batch` dispatches on bank rank:
    separable (N, K) Gaussian rows, or full (N, K, K) measured-PSF
    homogenization taps (DESIGN.md §7).  The engine's matched-pixel cache
    usually pre-applies this on the XLA path (then ``psf_kernels`` arrives
    as None here); this in-dispatch hook remains the uncached baseline and
    the distributed/mesh path.
    """
    if psf_kernels is not None:
        from repro.core import psf

        pixels = psf.convolve_batch(pixels, psf_kernels)
    if use_kernel:
        from repro.kernels.warp import ops as warp_ops

        if block_rows is None:
            block_rows = warp_ops.autotune_block_rows(
                grid_ra.shape[0], pixels.shape[1], pixels.shape[2]
            )
        return warp_ops.warp_batch(
            pixels, wcs_vecs, accept.astype(pixels.dtype), grid_ra, grid_dec,
            block_rows=block_rows, interpret=interpret,
        )
    return jax.vmap(project_one, in_axes=(0, 0, 0, None, None))(
        pixels, wcs_vecs, accept, grid_ra, grid_dec
    )
