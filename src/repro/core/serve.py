"""Coadd-as-a-service: the async multi-tenant query front end (DESIGN.md §10).

The paper's premise is throughput under load — a 400-node scheduler packing
nightly image streams onto busy machines — yet a bare `CoaddEngine` answers
one caller at a time.  This module is the serving layer on top of it, the
OpenCluster-style task queue adapted to the engine's actual economics:

  queue → admit → coalesce → dispatch → cache

* **Coalescing.**  Every request is planned at admission; plans that share a
  `CoaddPlan.coalesce_key` (layout, npix, grid override, PSF target — the
  `stack_plans` precondition) drain from the queue together and execute as
  ONE vmapped `execute_batch` dispatch.  K concurrent users, one jitted
  scan: exactly the Fig. 5 amortization the engine already optimizes for,
  triggered by load instead of by a caller who happened to batch.  The
  window for coalescing is natural, not a timer: while one dispatch holds
  the (single) engine worker, new arrivals pile up in the queue and the
  next drain takes them all — work-conserving, zero added latency at
  concurrency 1.  Requests with *identical* `result_key`s merge further
  (singleflight): one plan executes, every duplicate future resolves from
  the same pixels.

* **Admission / QoS.**  Load-shedding is typed and immediate: when the
  service already holds `max_queue` open requests (or a tenant its
  `tenant_inflight` cap), `submit` raises `Overloaded` instead of growing
  an unbounded queue.  Admitted plans are classed cheap/expensive on
  `CoaddPlan.cost_budget` — the §5 scan bucket that bounds dispatch time —
  and the drain cycle runs weighted round-robin between the classes
  (default 3:1 cheap), so a quarter-degree prefiltered query never queues
  behind a convoy of full-survey monsters: it waits at most the one
  dispatch already in flight plus its own.

* **Result cache.**  Completed pixels are kept in an LRU keyed on
  `CoaddEngine.result_key(plan)` — gate digest, qvec digest, layout/grid,
  live PSF state — whose contract is "equal keys ⇒ bitwise-equal coadds",
  so repeats are served from resident outputs without a scan (Kolosov's
  ingest-once/serve-forever).  With ``use_bricks=True``, brick-aligned
  queries route to the §9 mosaic path instead: warm covers are a
  one-dispatch mosaic of cached tiles, and the per-cover hit/miss tallies
  (`brick_popularity`) are the operator's signal for what to materialize
  next.  Lattice semantics note: aligned queries then answer on the global
  lattice window grid (bitwise-equal to `run_window`), like any
  `run(use_bricks=True)` call — unaligned queries are untouched.

* **Telemetry.**  `ServiceStats` mirrors the JobStats idiom: counters for
  admitted/shed/coalesced/cached, queue depth, and p50/p95/p99 latency,
  surfaced as a dataclass plus a JSON-ready `snapshot()`.

Threading model: asyncio front end, ONE `ThreadPoolExecutor` worker thread
for every engine touch (planning and dispatch both), so the engine — which
is not thread-safe — stays effectively single-threaded while the event
loop keeps admitting, shedding, and resolving futures.  All service state
(queue, cache, stats) is mutated only on the loop thread.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import CoaddEngine, CoaddResult
from repro.core.plan import CoaddPlan
from repro.core.query import CoaddQuery


class Overloaded(RuntimeError):
    """Typed admission rejection: the caller should back off and retry.

    ``reason`` is ``"queue_full"`` (service-wide open-request limit) or
    ``"tenant_cap"`` (per-tenant in-flight limit).  Raised *before* any
    engine work is spent on the request — shedding is the cheap path.
    """

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"service overloaded ({reason}): {detail}")
        self.reason = reason


@dataclasses.dataclass
class ServiceStats:
    """Serving telemetry, the JobStats of the front end.

    Counter groups: admission (submitted/admitted/shed_*), dispatch
    (dispatches + dispatched_queries → coalesce factor), result cache
    (hits/misses/merged_inflight), brick routing (§9), fault domain
    (retries observed in served results), and latency (p50/p95/p99 over
    completed requests, cache hits included).
    """

    submitted: int = 0
    admitted: int = 0
    shed_queue_full: int = 0
    shed_tenant_cap: int = 0
    completed: int = 0
    failed: int = 0
    # One "dispatch" = one engine entry (execute / execute_batch / brick
    # mosaic) the service issued; dispatched_queries = requests resolved by
    # those entries, in-flight merges included, cache hits excluded.
    dispatches: int = 0
    dispatched_queries: int = 0
    cheap_dispatches: int = 0
    expensive_dispatches: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    merged_inflight: int = 0
    brick_routed: int = 0
    bricks_hit: int = 0
    bricks_missed: int = 0
    retries: int = 0
    queue_depth: int = 0
    queue_depth_peak: int = 0
    latencies_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def shed(self) -> int:
        return self.shed_queue_full + self.shed_tenant_cap

    @property
    def coalesce_factor(self) -> float:
        """Requests answered per engine dispatch — the Fig. 5 amortization
        the queue achieved (1.0 = no coalescing happened)."""
        if self.dispatches == 0:
            return 0.0 if self.dispatched_queries == 0 else float("inf")
        return self.dispatched_queries / self.dispatches

    def latency_ms(self, pct: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), pct) * 1e3)

    @property
    def p50_ms(self) -> float:
        return self.latency_ms(50.0)

    @property
    def p95_ms(self) -> float:
        return self.latency_ms(95.0)

    @property
    def p99_ms(self) -> float:
        return self.latency_ms(99.0)

    def snapshot(self) -> Dict[str, float]:
        """JSON-ready view (drops the raw latency list)."""
        d = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name != "latencies_s"
        }
        d["coalesce_factor"] = round(self.coalesce_factor, 3)
        d["p50_ms"] = round(self.p50_ms, 3)
        d["p95_ms"] = round(self.p95_ms, 3)
        d["p99_ms"] = round(self.p99_ms, 3)
        return d


@dataclasses.dataclass(eq=False)  # identity equality: queue removal must
class _Pending:                   # never compare the numpy gate payloads
    """One admitted request waiting in the submission queue."""

    plan: CoaddPlan
    key: str                  # engine.result_key(plan) — merge identity
    cls: str                  # "cheap" | "expensive" (cost_budget class)
    tenant: str
    future: "asyncio.Future[CoaddResult]"


class CoaddService:
    """Async multi-tenant front end over one `CoaddEngine` (DESIGN.md §10).

    Usage::

        async with CoaddService(engine, max_queue=64) as svc:
            results = await asyncio.gather(
                *(svc.submit(q) for q in queries)
            )

    ``submit`` may also be called before `start`: requests queue up and the
    first drain after `start` coalesces them — the deterministic pattern
    the coalescing tests (and anyone replaying a recorded burst) use.

    Parameters
    ----------
    method : default locate method for `submit(query)` without one.
    max_queue : open-request limit; beyond it `submit` sheds `Overloaded`.
    max_batch : largest coalesced group per dispatch (vmap width cap).
    cheap_budget : `cost_budget` at or below which a plan classes cheap;
        None → P/4 of the plan's own layout (a quarter of the scan extent).
    cheap_weight : weighted-round-robin weight of the cheap class against
        1 for expensive.
    tenant_inflight : per-tenant open-request cap (None = uncapped).
    cache_entries : result-cache LRU capacity (0 disables caching).
    use_bricks : route brick-aligned queries to the §9 mosaic path and
        keep per-cover popularity tallies.
    """

    def __init__(
        self,
        engine: CoaddEngine,
        *,
        method: str = "sql_structured",
        max_queue: int = 64,
        max_batch: int = 16,
        cheap_budget: Optional[int] = None,
        cheap_weight: int = 3,
        tenant_inflight: Optional[int] = None,
        cache_entries: int = 128,
        use_bricks: bool = False,
    ):
        if max_queue <= 0:
            raise ValueError(f"max_queue must be positive, got {max_queue}")
        if max_batch <= 0:
            raise ValueError(f"max_batch must be positive, got {max_batch}")
        self.engine = engine
        self.method = method
        self.max_queue = max_queue
        self.max_batch = max_batch
        self.cheap_budget = cheap_budget
        self.cheap_weight = max(int(cheap_weight), 1)
        self.tenant_inflight = tenant_inflight
        self.cache_entries = cache_entries
        self.use_bricks = use_bricks

        self.stats = ServiceStats()
        # (band, r0, r1, c0, c1) cover tag -> [warm serves, cold misses]:
        # the §9 popularity signal for what to materialize / pin next.
        self.brick_popularity: Dict[Tuple, List[int]] = {}

        self._queue: Deque[_Pending] = collections.deque()
        self._cache: "collections.OrderedDict[str, CoaddResult]" = (
            collections.OrderedDict()
        )
        self._open_total = 0
        self._open_tenant: Dict[str, int] = collections.defaultdict(int)
        self._credits = {"cheap": 0.0, "expensive": 0.0}
        self._worker: Optional[ThreadPoolExecutor] = None
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._running = False

    # ----- lifecycle -----
    async def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._wake = asyncio.Event()
        if self._queue:
            self._wake.set()
        self._task = asyncio.get_running_loop().create_task(
            self._dispatch_loop()
        )

    async def stop(self) -> None:
        """Drain the queue, then stop the dispatcher (idempotent)."""
        if not self._running:
            return
        self._running = False
        self._wake.set()
        await self._task
        self._task = None
        if self._worker is not None:
            self._worker.shutdown(wait=True)
            self._worker = None

    async def __aenter__(self) -> "CoaddService":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    # ----- submission -----
    async def submit(
        self,
        query: CoaddQuery,
        method: Optional[str] = None,
        tenant: str = "default",
    ) -> CoaddResult:
        """Admit, plan, and eventually answer one query.

        Raises `Overloaded` (shed, before any engine work) or re-raises
        whatever fatal error the engine hit executing the plan.
        """
        m = method or self.method
        self.stats.submitted += 1
        if self._open_total >= self.max_queue:
            self.stats.shed_queue_full += 1
            raise Overloaded(
                "queue_full", f"{self._open_total} open >= {self.max_queue}"
            )
        cap = self.tenant_inflight
        if cap is not None and self._open_tenant[tenant] >= cap:
            self.stats.shed_tenant_cap += 1
            raise Overloaded(
                "tenant_cap", f"tenant {tenant!r} at {cap} in flight"
            )
        self.stats.admitted += 1
        self._open_total += 1
        self._open_tenant[tenant] += 1
        t0 = time.perf_counter()
        try:
            result = await self._serve(query, m)
        except Overloaded:
            raise
        except Exception:
            self.stats.failed += 1
            raise
        else:
            self.stats.completed += 1
            self.stats.latencies_s.append(time.perf_counter() - t0)
            return result
        finally:
            self._open_total -= 1
            self._open_tenant[tenant] -= 1

    async def _serve(self, query: CoaddQuery, method: str) -> CoaddResult:
        loop = asyncio.get_running_loop()
        if self.use_bricks:
            routed = await self._maybe_route_bricks(query, method)
            if routed is not None:
                return routed
        plan = await loop.run_in_executor(
            self._ensure_worker(), self.engine.plan, query, method
        )
        key = self.engine.result_key(plan)
        cached = self._cache_get(key)
        if cached is not None:
            self.stats.cache_hits += 1
            return cached
        self.stats.cache_misses += 1
        pend = _Pending(
            plan=plan,
            key=key,
            cls=self._classify(plan),
            tenant="",  # accounting lives in submit(); unused past here
            future=loop.create_future(),
        )
        self._queue.append(pend)
        self.stats.queue_depth = len(self._queue)
        self.stats.queue_depth_peak = max(
            self.stats.queue_depth_peak, self.stats.queue_depth
        )
        if self._wake is not None:
            self._wake.set()
        return await pend.future

    async def _maybe_route_bricks(
        self, query: CoaddQuery, method: str
    ) -> Optional[CoaddResult]:
        """Serve a brick-aligned query by the §9 mosaic path, or None.

        Aligned queries always take this path when ``use_bricks`` is on
        (cold covers materialize inline, exactly like `run(use_bricks=True)`)
        so their answers stay on the lattice grid regardless of store
        warmth; the warm/cold split only feeds the popularity tallies.
        """
        loop = asyncio.get_running_loop()
        cover = self.engine.brick_grid.decompose(query)
        if cover is None:
            return None
        # Store warmth is engine state — read it on the engine worker so it
        # never races a dispatch mutating the residency LRU.
        warm = (
            await loop.run_in_executor(
                self._ensure_worker(), self.engine.warm_brick_cover, query
            )
            is not None
        )
        tally = self.brick_popularity.setdefault(cover.tag, [0, 0])
        tally[0 if warm else 1] += 1
        # Mosaic pixels depend on the cover and the live PSF state, not on
        # the locate method (bricks are shared across methods).
        key = f"brick|{cover.tag}|{self.engine._psf_state()}"
        cached = self._cache_get(key)
        if cached is not None:
            self.stats.cache_hits += 1
            return cached
        self.stats.cache_misses += 1
        result = await loop.run_in_executor(
            self._ensure_worker(),
            lambda: self.engine.run(query, method, use_bricks=True),
        )
        self.stats.brick_routed += 1
        self.stats.bricks_hit += result.stats.bricks_hit
        self.stats.bricks_missed += result.stats.bricks_missed
        self.stats.retries += result.stats.retries
        self.stats.dispatches += 1
        self.stats.dispatched_queries += 1
        self._cache_put(key, result)
        return result

    # ----- dispatcher -----
    async def _dispatch_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            if not self._queue:
                if not self._running:
                    return
                self._wake.clear()
                if self._queue:  # raced with an enqueue
                    continue
                await self._wake.wait()
                continue
            group = self._select_group()
            self.stats.queue_depth = len(self._queue)
            if not group:
                continue
            try:
                uniq_keys, results = await loop.run_in_executor(
                    self._ensure_worker(), self._execute_group, group
                )
            except Exception as exc:
                for p in group:
                    if not p.future.done():
                        p.future.set_exception(exc)
                continue
            by_key = dict(zip(uniq_keys, results))
            self.stats.dispatches += 1
            self.stats.dispatched_queries += len(group)
            if group[0].cls == "cheap":
                self.stats.cheap_dispatches += 1
            else:
                self.stats.expensive_dispatches += 1
            self.stats.merged_inflight += len(group) - len(uniq_keys)
            for key, res in by_key.items():
                self.stats.retries += res.stats.retries
                self._cache_put(key, res)
            for p in group:
                if not p.future.done():
                    p.future.set_result(by_key[p.key])

    def _select_group(self) -> List[_Pending]:
        """Drain one coalescible group from the queue (loop thread).

        First resolves any pending whose key materialized in the cache
        since enqueue (an identical request completed meanwhile), then
        picks a class by weighted round-robin and takes every queued plan
        sharing the oldest pending's coalesce key, up to ``max_batch``.
        """
        for p in list(self._queue):
            hit = self._cache_get(p.key)
            if hit is not None:
                self._queue.remove(p)
                self.stats.cache_hits += 1
                # un-count the miss recorded at admission: it was served
                # from cache after all, never dispatched.
                self.stats.cache_misses -= 1
                if not p.future.done():
                    p.future.set_result(hit)
        if not self._queue:
            return []
        cheap = [p for p in self._queue if p.cls == "cheap"]
        expensive = [p for p in self._queue if p.cls == "expensive"]
        if cheap and expensive:
            total = self.cheap_weight + 1.0
            self._credits["cheap"] += self.cheap_weight
            self._credits["expensive"] += 1.0
            pick = (
                "cheap"
                if self._credits["cheap"] >= self._credits["expensive"]
                else "expensive"
            )
            self._credits[pick] -= total
        else:
            pick = "cheap" if cheap else "expensive"
        pool = cheap if pick == "cheap" else expensive
        lead = pool[0]
        group = [
            p for p in pool if p.plan.coalesce_key == lead.plan.coalesce_key
        ][: self.max_batch]
        for p in group:
            self._queue.remove(p)
        return group

    def _execute_group(
        self, group: List[_Pending]
    ) -> Tuple[List[str], List[CoaddResult]]:
        """Worker thread: merge identical plans, run ONE engine dispatch.

        A group of one runs the single-program `execute` path (bitwise
        trivially equal to `engine.run`); larger groups run the vmapped
        `execute_batch` over the de-duplicated plans.
        """
        uniq: "collections.OrderedDict[str, CoaddPlan]" = (
            collections.OrderedDict()
        )
        for p in group:
            uniq.setdefault(p.key, p.plan)
        plans = list(uniq.values())
        if len(plans) == 1:
            results = [self.engine.execute(plans[0])]
        else:
            results = self.engine.execute_batch(plans)
        return list(uniq.keys()), results

    # ----- helpers -----
    def _ensure_worker(self) -> ThreadPoolExecutor:
        if self._worker is None:
            self._worker = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="coadd-serve"
            )
        return self._worker

    def _classify(self, plan: CoaddPlan) -> str:
        cheap_at = self.cheap_budget
        if cheap_at is None:
            cheap_at = max(1, plan.gate.shape[0] // 4)
        return "cheap" if plan.cost_budget <= cheap_at else "expensive"

    def _cache_get(self, key: str) -> Optional[CoaddResult]:
        res = self._cache.get(key)
        if res is not None:
            self._cache.move_to_end(key)
        return res

    def _cache_put(self, key: str, result: CoaddResult) -> None:
        if self.cache_entries <= 0:
            return
        self._cache[key] = result
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_entries:
            self._cache.popitem(last=False)


__all__ = ["CoaddService", "Overloaded", "ServiceStats"]
