"""Prefilters: glob-style metadata pruning and the exact "SQL" spatial index.

Paper §4.1.1: the SDSS directory layout encodes (band, camcol) in filenames,
so a glob like ``corr/[234]/fpC-*-[g][234]-*.fit`` excludes irrelevant files
before the job starts.  The filter is *single-axis* (camcol = declination
strip); it cannot prune along RA, so false positives remain and are
discarded inside the mappers (Fig. 6).

Paper §4.1.4: an external SQL database over per-file metadata (band +
sky-bounds + sequence-file offsets) returns *exactly* the contributing
files — zero false positives — which are then gathered from the containers
via the index.

Here the glob becomes a vectorized mask over metadata columns (band equality
+ camcol/dec-strip overlap only), and "SQL" becomes `SpatialIndex`, a
host-side sorted-interval index supporting exact band+box+time selection.
Both operate on metadata only — never pixels — exactly like the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.query import CoaddQuery
from repro.core.seqfile import PackedDataset
from repro.core.survey import Survey


def glob_file_mask(tab: dict, query: CoaddQuery, camcol_dec_ranges: np.ndarray) -> np.ndarray:
    """Glob-equivalent prefilter over individual files.

    Accepts files whose band matches and whose *camcol strip* (not the file's
    own RA bounds!) overlaps the query dec range.  Single-axis, with false
    positives along RA — faithful to §4.1.1.
    """
    band_ok = tab["band_id"] == query.band_id
    dec0, dec1 = query.dec_bounds
    strips = camcol_dec_ranges[tab["camcol"]]
    dec_ok = (strips[:, 1] >= dec0) & (strips[:, 0] <= dec1)
    return band_ok & dec_ok


def glob_pack_mask(ds: PackedDataset, query: CoaddQuery, camcol_dec_ranges: np.ndarray) -> np.ndarray:
    """Container-level pruning for structured packs (paper §4.1.3).

    Unstructured packs (key -1) can never be pruned — the paper's point.
    """
    band_ok = (ds.pack_band == query.band_id) | (ds.pack_band < 0)
    cc = np.clip(ds.pack_camcol, 0, None)
    strips = camcol_dec_ranges[cc]
    dec0, dec1 = query.dec_bounds
    dec_ok = (strips[:, 1] >= dec0) & (strips[:, 0] <= dec1) | (ds.pack_camcol < 0)
    return band_ok & dec_ok


def camcol_dec_table(survey: Survey) -> np.ndarray:
    """(n_camcols, 2) dec range per camera column, from survey metadata."""
    tab = survey.meta_table()
    n = survey.config.n_camcols
    out = np.zeros((n, 2), np.float32)
    for c in range(n):
        sel = tab["camcol"] == c
        out[c, 0] = tab["dec_min"][sel].min()
        out[c, 1] = tab["dec_max"][sel].max()
    return out


@dataclasses.dataclass
class SpatialIndex:
    """Exact metadata index over the archive (the paper's external SQL DB).

    Stores band, RA/Dec bounds, observation time and the sequence-file
    location of every image; `select` answers a query with exactly the
    overlapping image ids (no false positives / negatives).
    """

    image_id: np.ndarray
    band_id: np.ndarray
    ra_min: np.ndarray
    ra_max: np.ndarray
    dec_min: np.ndarray
    dec_max: np.ndarray
    t_obs: np.ndarray
    order: np.ndarray  # image ids sorted by ra_min, per band

    @staticmethod
    def build(survey: Survey) -> "SpatialIndex":
        tab = survey.meta_table()
        return SpatialIndex(
            image_id=tab["image_id"],
            band_id=tab["band_id"],
            ra_min=tab["ra_min"],
            ra_max=tab["ra_max"],
            dec_min=tab["dec_min"],
            dec_max=tab["dec_max"],
            t_obs=tab["t_obs"],
            order=np.argsort(tab["ra_min"], kind="stable"),
        )

    def select(self, query: CoaddQuery) -> np.ndarray:
        """Exact overlap selection (band AND box AND optional time window)."""
        ra0, ra1 = query.ra_bounds
        dec0, dec1 = query.dec_bounds
        t0, t1 = query.time_window()
        m = (
            (self.band_id == query.band_id)
            & (self.ra_max >= ra0)
            & (self.ra_min <= ra1)
            & (self.dec_max >= dec0)
            & (self.dec_min <= dec1)
            & (self.t_obs >= t0)
            & (self.t_obs <= t1)
        )
        return self.image_id[m]
