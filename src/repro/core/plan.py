"""Query planning: the host-side half of the plan/execute engine split.

The paper's framing is that input format determines *job-init* cost, not
mapper arithmetic: the six methods differ only in how the set of candidate
images is located.  A `CoaddPlan` captures exactly that job-init product —
which layout to scan, the static-shape (P, cap) slot gate selecting its
candidate slots, and the query vector the device-side acceptance test needs
— plus the host time spent locating (the paper's "construct file splits"
phase, Fig. 8).

Because a plan is pure data, the same plan runs anywhere: `CoaddEngine.run`
executes one against the device-resident layout, `run_batch` stacks several
plans for a shared layout into one vmapped dispatch (the paper's Fig. 5
multi-query amortization), and `run_distributed` builds the flattened
equivalent against a mesh-resident layout.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.query import CoaddQuery


@dataclasses.dataclass
class CoaddPlan:
    """One planned query: layout + slot gate + query vector + locate stats."""

    method: str
    layout: str            # "per_file" | "unstructured" | "structured"
    gate: np.ndarray       # (P, cap) bool — static shape, dynamic values
    qvec: np.ndarray       # (7,) float32 device-side acceptance vector
    query: CoaddQuery
    t_locate_s: float      # host job-init cost (prefilter/index, Fig. 8)

    @property
    def npix(self) -> int:
        return self.query.npix

    @property
    def packs_touched(self) -> int:
        """Distinct containers the gate opens (§4.1.4 locality statistic)."""
        return int(self.gate.any(axis=1).sum())


def stack_plans(plans: Sequence[CoaddPlan]) -> Tuple[np.ndarray, np.ndarray]:
    """Stack same-layout plans into batched (K, P, cap) gates + (K, 7) qvecs.

    One batched job must share a layout (one resident dataset to scan) and an
    output grid size (one static scan program); both are validated here so
    `run_batch` fails loudly at plan time, not at trace time.
    """
    if not plans:
        raise ValueError("cannot stack zero plans")
    layouts = {p.layout for p in plans}
    if len(layouts) != 1:
        raise ValueError(f"batched plans must share a layout, got {layouts}")
    npixes = {p.npix for p in plans}
    if len(npixes) != 1:
        raise ValueError(f"batched plans must share npix, got {npixes}")
    gates = np.stack([p.gate for p in plans])
    qvecs = np.stack([p.qvec for p in plans])
    return gates, qvecs


__all__: List[str] = ["CoaddPlan", "stack_plans"]
