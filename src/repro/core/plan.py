"""Query planning: the host-side half of the plan/execute engine split.

The paper's framing is that input format determines *job-init* cost, not
mapper arithmetic: the six methods differ only in how the set of candidate
images is located.  A `CoaddPlan` captures exactly that job-init product —
which layout to scan, the static-shape (P, cap) slot gate selecting its
candidate slots, and the query vector the device-side acceptance test needs
— plus the host time spent locating (the paper's "construct file splits"
phase, Fig. 8).

Because a plan is pure data, the same plan runs anywhere: `CoaddEngine.run`
executes one against the device-resident layout, `run_batch` stacks several
plans for a shared layout into one vmapped dispatch (the paper's Fig. 5
multi-query amortization), and `run_distributed` builds the flattened
equivalent against a mesh-resident layout.

Sparse execution (DESIGN.md §5): a gate also *plans the scan extent*.  The
paper's central win is refusing to pay mapper cost for images a query does
not need (SQL prefiltering, Fig. 8); `sparse_pack_index` carries that win
across the execute boundary by deriving from a gate the list of pack indices
it actually opens, padded up to a static *budget bucket* (powers of two,
capped at P) so a handful of compiled programs serve every selectivity.  The
executor gathers just those packs out of the resident layout with
``jnp.take`` and scans the compacted arrays — map work scales with
``packs_touched`` instead of P.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.query import CoaddQuery


@dataclasses.dataclass
class CoaddPlan:
    """One planned query: layout + slot gate + query vector + locate stats."""

    method: str
    layout: str            # "per_file" | "unstructured" | "structured"
    gate: np.ndarray       # (P, cap) bool — static shape, dynamic values
    qvec: np.ndarray       # (7,) float32 device-side acceptance vector
    query: CoaddQuery
    t_locate_s: float      # host job-init cost (prefilter/index, Fig. 8)
    # PSF homogenization target the plan was built under (None = matching
    # off).  Executors validate it against their own configuration: kernel
    # banks and the matched-pixel cache are keyed per target, so running a
    # stale plan on a retuned engine would silently stack mismatched PSFs.
    psf_target: Optional[float] = None
    # Output-grid override (DESIGN.md §9): precomputed (ra, dec) float32
    # sky coords, each (npix, npix), replacing the query's own TAN grid.
    # Brick plans use this to put every brick (and brick window) on the one
    # global lattice, which is what makes mosaicked and fresh scans agree
    # bitwise; None (the default) keeps the per-query grid.
    grid_sky: Optional[Tuple[np.ndarray, np.ndarray]] = None
    # Reduction variant (DESIGN.md §11): "mean" is the paper's monoidal
    # accumulate; "clipped"/"median" are the robust two-pass stacks.  Part
    # of the plan because it changes both the executed program and the
    # output bytes — caches, coalescing, and journals must distinguish it.
    reduce: str = "mean"

    @property
    def npix(self) -> int:
        return self.query.npix

    @property
    def packs_touched(self) -> int:
        """Distinct containers the gate opens (§4.1.4 locality statistic)."""
        return int(self.gate.any(axis=1).sum())

    @property
    def cost_budget(self) -> int:
        """The §5 budget bucket this plan will scan at — the admission-control
        cost signal (DESIGN.md §10).  A quarter-degree prefiltered query
        buckets to a handful of packs; a full-survey raw query buckets to P.
        The service's two-class scheduler splits cheap from expensive on
        exactly this number, because it is what bounds the compiled scan
        extent (and hence the dispatch time) the queue pays to run the plan.
        """
        return scan_budget(self.packs_touched, self.gate.shape[0])

    @property
    def coalesce_key(self) -> Tuple[str, int, str, Optional[float], str]:
        """Compatibility class for batching (DESIGN.md §10).

        Plans coalesce into one vmapped `run_batch` dispatch iff they share
        a resident layout, an output grid size (one static scan program), a
        grid override (brick-lattice plans must not stack with query-grid
        plans), a PSF target (executors reject cross-target plans), and a
        reduction variant (a clipped batch runs a different program than a
        mean batch).  This is exactly the precondition `stack_plans`
        validates, lifted to a hashable key the dispatcher can group by.
        """
        return (self.layout, self.npix, grid_digest(self.grid_sky),
                self.psf_target, self.reduce)

    @property
    def fingerprint(self) -> str:
        """Value identity of this plan's *pixels*, independent of locate path.

        A digest over everything that determines the coadd bytes — layout,
        output grid (size + override), PSF target, gate bytes, query vector
        — but *not* the method name: methods differ in job-init cost, never
        in accepted images.  The serving result cache keys on this (plus the
        engine's live PSF state), so a repeat query is served from resident
        outputs without re-scanning (Kolosov's ingest-once/serve-forever).
        """
        h = hashlib.sha256()
        h.update(
            f"{self.layout}|{self.npix}|{self.psf_target}"
            f"|{grid_digest(self.grid_sky)}|{self.reduce}".encode()
        )
        h.update(np.ascontiguousarray(self.gate).tobytes())
        h.update(np.ascontiguousarray(self.qvec, np.float32).tobytes())
        return h.hexdigest()


def grid_digest(
    grid_sky: Optional[Tuple[np.ndarray, np.ndarray]]
) -> str:
    """Digest of an output-grid override (empty string = default query grid).

    Shared by the engine's journal identity (`_job_key` must distinguish a
    lattice-window scan from the plain query-grid scan of the same bounds)
    and the plan coalesce/fingerprint keys above.
    """
    if grid_sky is None:
        return ""
    h = hashlib.sha256()
    for g in grid_sky:
        h.update(np.ascontiguousarray(g, np.float32).tobytes())
    return h.hexdigest()[:16]


def scan_budget(n_gated: int, n_packs: int) -> int:
    """Static scan extent for a gate opening ``n_gated`` of ``n_packs`` packs.

    Buckets to the next power of two (minimum 1, capped at ``n_packs``) so
    the number of distinct compiled sparse programs per layout is bounded by
    log2(P) — selectivity varies per query, recompiles don't.  An empty gate
    still budgets one pack: the executor scans a single all-False slot row,
    which yields an exact-zero coadd without a zero-length scan.
    """
    if n_packs <= 0:
        raise ValueError(f"n_packs must be positive, got {n_packs}")
    n = max(int(n_gated), 1)
    bucket = 1
    while bucket < n:
        bucket <<= 1
    return min(bucket, n_packs)


@dataclasses.dataclass
class SparseScanIndex:
    """A gate's padded pack-index vector: which packs to gather, and how many.

    ``pack_idx`` has static length ``budget`` (= `scan_budget` bucket);
    entries past ``n_gated`` are padding (index 0) that the compacted gate
    masks to all-False, so duplicates contribute exact zeros.
    """

    pack_idx: np.ndarray   # (budget,) int32 indices into the pack axis
    n_gated: int           # packs the gate actually opens
    budget: int            # static bucket == len(pack_idx)
    n_packs: int           # pack count of the layout the gate addresses

    @property
    def worthwhile(self) -> bool:
        """Gathering pays only when the bucket is smaller than the layout."""
        return self.budget < self.n_packs


def sparse_pack_index(gate: np.ndarray) -> SparseScanIndex:
    """Derive the padded pack-index vector a (P, cap) gate opens."""
    packs = np.nonzero(gate.any(axis=1))[0]
    n_packs = gate.shape[0]
    budget = scan_budget(len(packs), n_packs)
    idx = np.zeros((budget,), np.int32)
    idx[: len(packs)] = packs[:budget]
    return SparseScanIndex(idx, len(packs), budget, n_packs)


def compact_gate(gate: np.ndarray, sp: SparseScanIndex) -> np.ndarray:
    """(P, cap) gate -> (budget, cap) gate over the gathered packs.

    Padding rows are forced False so the duplicate pack-0 entries `jnp.take`
    gathers for them are rejected by the acceptance test.
    """
    g = gate[sp.pack_idx].copy()
    g[sp.n_gated :] = False
    return g


@dataclasses.dataclass
class ScanWindow:
    """One streaming-residency window: a chunk of packs plus the scan over it.

    The streaming executor (DESIGN.md §6) cannot assume the whole layout is
    device-resident, so a query's gated pack set is partitioned by *chunk* —
    the contiguous pack-range granule the `ResidencyManager` uploads and
    evicts.  Each window scans one chunk with the same budget-bucketed
    sparse program as §5, just with chunk-local indices; window results are
    additive (the reduce monoid), so the executor streams chunk N+1's upload
    behind chunk N's scan and blocks once at the end.
    """

    start: int             # chunk pack-range [start, stop) in layout coords
    stop: int
    sel: np.ndarray        # (n_gated,) *global* pack indices inside the chunk
    pack_idx: np.ndarray   # (budget,) chunk-local indices, 0-padded
    n_gated: int
    budget: int            # static bucket == len(pack_idx)

    @property
    def key(self) -> Tuple[int, int, int, int]:
        """Journal identity of this window *within one query's schedule*.

        The fault tracker (DESIGN.md §8) journals completed window partials
        under this key; it is unique within a schedule because windows
        partition the pack range.  Cross-query identity comes from the
        engine's job key (a digest over gate/qvec/schedule), never from
        this tuple alone.
        """
        return (self.start, self.stop, self.n_gated, self.budget)


def window_schedule(
    gated: np.ndarray, n_packs: int, chunk_packs: int
) -> List[ScanWindow]:
    """Partition a sorted gated-pack vector into chunk-aligned scan windows.

    Chunks with no gated pack produce no window (their bytes never upload);
    an empty gate still yields one single-pack window so the executor keeps
    the §5 empty-gate contract: one dispatch, an all-False row, exact zeros.
    """
    if chunk_packs <= 0:
        raise ValueError(f"chunk_packs must be positive, got {chunk_packs}")
    if len(gated) == 0:
        return [
            ScanWindow(
                0,
                min(chunk_packs, n_packs),
                np.empty((0,), np.int64),
                np.zeros((1,), np.int32),
                0,
                1,
            )
        ]
    windows: List[ScanWindow] = []
    for c in range(0, n_packs, chunk_packs):
        stop = min(c + chunk_packs, n_packs)
        sel = gated[(gated >= c) & (gated < stop)]
        if len(sel) == 0:
            continue
        budget = scan_budget(len(sel), stop - c)
        idx = np.zeros((budget,), np.int32)
        idx[: len(sel)] = sel - c
        windows.append(ScanWindow(c, stop, sel, idx, len(sel), budget))
    return windows


def compact_window_gate(gate: np.ndarray, win: ScanWindow) -> np.ndarray:
    """(P, cap) gate -> (budget, cap) gate over one window's gathered packs."""
    out = np.zeros((win.budget, gate.shape[-1]), bool)
    out[: win.n_gated] = gate[win.sel]
    return out


def compact_window_gates(gates: np.ndarray, win: ScanWindow) -> np.ndarray:
    """(K, P, cap) gates -> (K, budget, cap) over one window's packs."""
    out = np.zeros((gates.shape[0], win.budget, gates.shape[-1]), bool)
    out[:, : win.n_gated] = gates[:, win.sel]
    return out


def union_sparse_index(gates: np.ndarray) -> SparseScanIndex:
    """Sparse index for a (K, P, cap) stack of gates: union over queries.

    `run_batch` scans one compacted layout for the whole batch, so the
    gather set is the union of every query's packs; each query's compacted
    gate (`compact_gates`) then re-selects its own slots within it.
    """
    return sparse_pack_index(gates.any(axis=0))


def compact_gates(gates: np.ndarray, sp: SparseScanIndex) -> np.ndarray:
    """(K, P, cap) gates -> (K, budget, cap) over the union-gathered packs."""
    g = gates[:, sp.pack_idx].copy()
    g[:, sp.n_gated :] = False
    return g


def stack_plans(plans: Sequence[CoaddPlan]) -> Tuple[np.ndarray, np.ndarray]:
    """Stack same-layout plans into batched (K, P, cap) gates + (K, 7) qvecs.

    One batched job must share a layout (one resident dataset to scan) and an
    output grid size (one static scan program); both are validated here so
    `run_batch` fails loudly at plan time, not at trace time.
    """
    if not plans:
        raise ValueError("cannot stack zero plans")
    layouts = {p.layout for p in plans}
    if len(layouts) != 1:
        raise ValueError(f"batched plans must share a layout, got {layouts}")
    npixes = {p.npix for p in plans}
    if len(npixes) != 1:
        raise ValueError(f"batched plans must share npix, got {npixes}")
    reduces = {p.reduce for p in plans}
    if len(reduces) != 1:
        raise ValueError(f"batched plans must share a reduce, got {reduces}")
    gates = np.stack([p.gate for p in plans])
    qvecs = np.stack([p.qvec for p in plans])
    return gates, qvecs


__all__: List[str] = [
    "CoaddPlan",
    "ScanWindow",
    "SparseScanIndex",
    "compact_gate",
    "compact_gates",
    "compact_window_gate",
    "compact_window_gates",
    "grid_digest",
    "scan_budget",
    "sparse_pack_index",
    "stack_plans",
    "union_sparse_index",
    "window_schedule",
]
