"""Difference imaging + on-device source detection (DESIGN.md §11).

The paper's motivating workload is nightly transient detection: coaddition
is the *preprocessing* step whose product — a deep, PSF-homogenized
template — new epochs are differenced against (§1; Kolosov's
ingest-once/reuse-forever architecture makes the materialized brick coadds
of §9 exactly that template).  This module closes the loop:

* ``inject_transients``   — seeded synthetic transients splatted into one
  epoch of a survey (host-side, before any engine sees the pixels), so the
  drill has ground truth.
* ``difference_image``    — new-epoch stack minus the brick-served template,
  both depth-normalized, both PSF-homogenized by the engine's matching bank
  (set ``match_psf_sigma`` so epoch and template share one effective PSF).
* ``detect_sources``      — sep-style thresholded detection, entirely on
  device and jit-compiled: per-pixel noise scaling from the two depth maps,
  a robust MAD noise floor, 3x3 local-maximum peak finding, and a static
  top-K extraction emitting (x, y, flux, npix, snr) rows.
* ``match_detections``    — grades a catalog against the injected ground
  truth (recovered / spurious) for the acceptance drill.

Detection is deliberately *relative*: the difference is scored in units of
its own robust noise, so the drill needs no knowledge of the survey's
absolute noise level.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import sky_to_pixel
from repro.core.query import CoaddQuery
from repro.core.survey import Survey


@dataclasses.dataclass
class DetectionCatalog:
    """Thresholded detections from one difference image (host arrays)."""

    x: np.ndarray       # (n,) int32 column of each peak on the output grid
    y: np.ndarray       # (n,) int32 row
    flux: np.ndarray    # (n,) float32 3x3 aperture sum of the difference
    npix: np.ndarray    # (n,) int32 above-threshold pixels in the 3x3 box
    snr: np.ndarray     # (n,) float32 peak significance in MAD-sigma units

    def __len__(self) -> int:
        return int(self.x.shape[0])


def epoch_time_bounds(survey: Survey, run: Optional[int] = None
                      ) -> Tuple[float, float]:
    """The ``time_bounds`` window selecting exactly one run (epoch).

    The synthetic survey stamps ``t_obs = run * 100 + field``; the default
    is the final run — the "tonight" epoch a nightly pipeline differences.
    """
    if run is None:
        run = survey.config.n_runs - 1
    return (float(run * 100), float(run * 100 + 99))


def inject_transients(
    survey: Survey,
    query: CoaddQuery,
    n: int = 8,
    flux: float = 400.0,
    run: Optional[int] = None,
    seed: int = 7,
    margin_frac: float = 0.12,
    min_sep_px: float = 6.0,
) -> np.ndarray:
    """Splat ``n`` seeded point transients into one epoch of ``survey``.

    Positions are drawn uniformly inside the query box (shrunk by
    ``margin_frac`` so every source lands fully on the output grid, and
    rejection-sampled to pairwise separations of at least ``min_sep_px``
    grid pixels — detection is peak finding, not deblending, so the drill
    must not grade blends); each transient is a Gaussian of total ``flux``
    at the *image's own* seeing, added host-side to every covering frame of
    the chosen run+band — mutating the survey in place BEFORE any engine
    ingests it, exactly like a real variable sky.  Returns the (n, 2)
    array of (ra, dec) truths.
    """
    if run is None:
        run = survey.config.n_runs - 1
    rng = np.random.default_rng(seed)
    ra0, ra1 = query.ra_bounds
    dec0, dec1 = query.dec_bounds
    mra, mdec = margin_frac * (ra1 - ra0), margin_frac * (dec1 - dec0)
    ras_l: List[float] = []
    decs_l: List[float] = []
    gx: List[float] = []
    gy: List[float] = []
    for _ in range(10000):
        if len(ras_l) >= n:
            break
        ra = rng.uniform(ra0 + mra, ra1 - mra)
        dec = rng.uniform(dec0 + mdec, dec1 - mdec)
        x, y = sky_to_grid(query, np.array([ra]), np.array([dec]))
        if any((x[0] - a) ** 2 + (y[0] - b) ** 2 < min_sep_px ** 2
               for a, b in zip(gx, gy)):
            continue
        ras_l.append(ra)
        decs_l.append(dec)
        gx.append(float(x[0]))
        gy.append(float(y[0]))
    if len(ras_l) < n:
        raise ValueError(
            f"could not place {n} transients {min_sep_px}px apart"
        )
    ras, decs = np.array(ras_l), np.array(decs_l)
    for im in survey.images:
        if im.run != run or im.band != query.band:
            continue
        h, w = im.pixels.shape
        v = im.wcs.to_vector().astype(np.float64)
        px, py = sky_to_pixel(ras, decs, v)
        ys, xs = np.mgrid[0:h, 0:w]
        for cx, cy in zip(px, py):
            if not (-1 < cx < w and -1 < cy < h):
                continue
            s = float(im.psf_sigma)
            prof = np.exp(
                -((xs - cx) ** 2 + (ys - cy) ** 2) / (2.0 * s * s)
            ) / (2.0 * np.pi * s * s)
            im.pixels += (flux * prof).astype(im.pixels.dtype)
    return np.stack([ras, decs], axis=1)


def difference_image(
    engine,
    query: CoaddQuery,
    run: Optional[int] = None,
    method: str = "sql_structured",
    reduce: str = "mean",
    use_bricks: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """New-epoch stack minus the all-epoch template, depth-normalized.

    The template is served from the materialized brick coadds when the
    query decomposes (``use_bricks`` — the §9 reuse-forever path); the
    epoch is a normal time-bounded query through the same engine, so both
    sides share the PSF-homogenization bank.  Returns
    ``(diff, depth_epoch, depth_template)`` as host float arrays.
    """
    bounds = epoch_time_bounds(engine.survey, run)
    epoch_q = dataclasses.replace(query, time_bounds=bounds)
    template = engine.run(query, method, use_bricks=use_bricks, reduce=reduce)
    epoch = engine.run(epoch_q, method, reduce=reduce)
    diff = epoch.normalized - template.normalized
    return diff, epoch.depth, template.depth


@partial(jax.jit, static_argnames=("max_sources",))
def _detect(diff, depth_a, depth_b, nsigma, max_sources):
    q = diff.shape[0]
    valid = (depth_a > 0) & (depth_b > 0)
    # Per-pixel noise of a difference of two depth-normalized stacks scales
    # as sqrt(1/Na + 1/Nb); the absolute noise level is calibrated away by
    # the MAD floor below, so only the *relative* scale matters.
    scale = jnp.sqrt(
        1.0 / jnp.where(valid, depth_a, 1.0)
        + 1.0 / jnp.where(valid, depth_b, 1.0)
    )
    r = jnp.where(valid, diff / scale, jnp.nan)
    med = jnp.nanmedian(r)
    sigma1 = 1.4826 * jnp.nanmedian(jnp.abs(r - med)) + 1e-12
    snr = jnp.where(valid, (r - med) / sigma1, 0.0)

    neigh_max = jax.lax.reduce_window(
        snr, -jnp.inf, jax.lax.max, (3, 3), (1, 1), "SAME"
    )
    above = (snr >= nsigma) & valid
    peaks = above & (snr >= neigh_max)
    box_flux = jax.lax.reduce_window(
        jnp.where(valid, diff, 0.0), 0.0, jax.lax.add, (3, 3), (1, 1), "SAME"
    )
    box_npix = jax.lax.reduce_window(
        above.astype(jnp.int32), 0, jax.lax.add, (3, 3), (1, 1), "SAME"
    )

    score = jnp.where(peaks, snr, -jnp.inf).reshape(-1)
    top, idx = jax.lax.top_k(score, max_sources)
    count = jnp.minimum(peaks.sum(), max_sources)
    return (
        (idx % q).astype(jnp.int32),
        (idx // q).astype(jnp.int32),
        box_flux.reshape(-1)[idx],
        box_npix.reshape(-1)[idx],
        top,
        count,
    )


def detect_sources(
    diff: np.ndarray,
    depth_epoch: np.ndarray,
    depth_template: np.ndarray,
    nsigma: float = 5.0,
    max_sources: int = 32,
) -> DetectionCatalog:
    """sep-style thresholded detection on a difference image, on device.

    A pixel is a detection seed when its depth-scaled, MAD-normalized
    significance exceeds ``nsigma`` AND it is the maximum of its 3x3
    neighborhood (one catalog row per source, not per bright pixel).  The
    extraction is a static ``top_k`` so the program has one shape for any
    source count; rows beyond the true count are dropped host-side.
    """
    x, y, flux, npix, snr, count = _detect(
        jnp.asarray(diff, jnp.float32),
        jnp.asarray(depth_epoch, jnp.float32),
        jnp.asarray(depth_template, jnp.float32),
        jnp.float32(nsigma),
        int(max_sources),
    )
    k = int(count)
    return DetectionCatalog(
        x=np.asarray(x)[:k],
        y=np.asarray(y)[:k],
        flux=np.asarray(flux)[:k],
        npix=np.asarray(npix)[:k],
        snr=np.asarray(snr)[:k],
    )


def sky_to_grid(query: CoaddQuery, ra: np.ndarray, dec: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray]:
    """(ra, dec) -> fractional (x, y) on the query's output grid."""
    g = query.grid_wcs_vector().astype(np.float64)
    return sky_to_pixel(np.asarray(ra, np.float64),
                        np.asarray(dec, np.float64), g)


def match_detections(
    catalog: DetectionCatalog,
    query: CoaddQuery,
    truth_radec: np.ndarray,
    tol_px: float = 3.0,
) -> Tuple[int, int]:
    """Grade a catalog against injected truths: (recovered, spurious).

    A truth is recovered when some detection lies within ``tol_px`` of its
    grid position; a detection matching no truth is spurious (the static-sky
    drill demands zero of those).
    """
    if len(truth_radec):
        tx, ty = sky_to_grid(query, truth_radec[:, 0], truth_radec[:, 1])
    else:
        tx = ty = np.zeros(0)
    if len(catalog) == 0:
        return 0, 0
    dx = catalog.x[None, :] - tx[:, None]
    dy = catalog.y[None, :] - ty[:, None]
    close = (dx * dx + dy * dy) <= tol_px * tol_px
    recovered = int(close.any(axis=1).sum()) if close.size else 0
    spurious = int((~close.any(axis=0)).sum()) if close.size else len(catalog)
    return recovered, spurious
