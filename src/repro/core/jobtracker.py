"""JobTracker: Hadoop-style task re-execution and speculative dispatch.

MapReduce's scaling premise (paper §3): at thousands of nodes, failures are
the norm; the framework hides them by re-executing failed tasks and
launching redundant ("speculative") copies of stragglers.  That machinery is
what lets the coadd job survive node loss.

On a TPU pod the analogue is necessarily different — an SPMD program cannot
lose one participant mid-collective — so fault handling moves up a level:

* the *work decomposition* stays Hadoop-shaped: the image set is split into
  idempotent, journaled map tasks whose outputs combine through a
  commutative monoid (coadd accumulation), so any task may be re-executed
  or executed twice without changing the result;
* task completion is journaled with a content digest; restart replays only
  missing tasks (checkpoint/restart at the job level);
* stragglers get speculative backups — first result wins, digests must
  agree (determinism check);
* elastic scaling: the task list can be re-partitioned over a different
  worker count between (re)starts, because tasks are location-free.

The same pattern backs the training loop's checkpoint/restart in
`repro.launch.train`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class MapTask:
    task_id: int
    image_ids: np.ndarray  # the shard of images this task maps


@dataclasses.dataclass
class TaskResult:
    task_id: int
    coadd: np.ndarray
    depth: np.ndarray
    digest: str
    attempts: int
    worker: int


def _digest(coadd: np.ndarray, depth: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(coadd, np.float32).tobytes())
    h.update(np.ascontiguousarray(depth, np.float32).tobytes())
    return h.hexdigest()[:16]


class FailureInjector:
    """Deterministic failure/straggler schedule for tests and drills.

    fail_plan: {(task_id, attempt): "fail" | "slow"}.
    """

    def __init__(self, plan: Optional[Dict] = None, slow_s: float = 0.0):
        self.plan = plan or {}
        self.slow_s = slow_s

    def before_run(self, task_id: int, attempt: int):
        kind = self.plan.get((task_id, attempt))
        if kind == "fail":
            raise RuntimeError(f"injected failure: task {task_id} attempt {attempt}")
        if kind == "slow" and self.slow_s:
            time.sleep(self.slow_s)


class JobTracker:
    """Executes map tasks with journaling, retry, and speculative backup.

    ``executor(image_ids) -> (coadd, depth)`` must be deterministic in its
    inputs (ours is: jit'd pure function over seeded data), which the tracker
    *verifies* when speculation produces two results for one task.
    """

    def __init__(
        self,
        executor: Callable[[np.ndarray], tuple],
        n_workers: int = 4,
        max_attempts: int = 3,
        straggler_threshold_s: float = float("inf"),
        injector: Optional[FailureInjector] = None,
    ):
        self.executor = executor
        self.n_workers = n_workers
        self.max_attempts = max_attempts
        self.straggler_threshold_s = straggler_threshold_s
        self.injector = injector or FailureInjector()
        self.journal: Dict[int, TaskResult] = {}
        self.events: List[str] = []

    @staticmethod
    def split(image_ids: np.ndarray, n_tasks: int) -> List[MapTask]:
        """Location-free task partition (supports elastic re-partitioning)."""
        chunks = np.array_split(np.asarray(image_ids), n_tasks)
        return [MapTask(i, c) for i, c in enumerate(chunks) if len(c)]

    def _attempt(self, task: MapTask, attempt: int, worker: int) -> TaskResult:
        self.injector.before_run(task.task_id, attempt)
        t0 = time.perf_counter()
        coadd, depth = self.executor(task.image_ids)
        dt = time.perf_counter() - t0
        res = TaskResult(
            task.task_id, np.asarray(coadd), np.asarray(depth), "", attempt, worker
        )
        res.digest = _digest(res.coadd, res.depth)
        if dt > self.straggler_threshold_s:
            # Straggler: speculative backup on another worker; first-completed
            # semantics — here sequential, so verify digests agree instead.
            self.events.append(f"speculative task={task.task_id}")
            backup = self.executor(task.image_ids)
            bd = _digest(np.asarray(backup[0]), np.asarray(backup[1]))
            if bd != res.digest:
                raise RuntimeError(
                    f"nondeterministic task {task.task_id}: {res.digest} != {bd}"
                )
        return res

    def run(self, tasks: Sequence[MapTask]) -> tuple:
        """Run all tasks (skipping journaled ones), return combined coadd."""
        for ti, task in enumerate(tasks):
            if task.task_id in self.journal:
                self.events.append(f"journal-hit task={task.task_id}")
                continue
            worker = ti % self.n_workers
            for attempt in range(self.max_attempts):
                try:
                    res = self._attempt(task, attempt, worker)
                    self.journal[task.task_id] = res
                    break
                except RuntimeError as e:  # noqa: PERF203
                    self.events.append(f"retry task={task.task_id} attempt={attempt}: {e}")
                    worker = (worker + 1) % self.n_workers  # reschedule elsewhere
            else:
                raise RuntimeError(f"task {task.task_id} exhausted retries")
        # Commutative-monoid combine: order-independent.
        results = [self.journal[t.task_id] for t in tasks]
        coadd = np.sum([r.coadd for r in results], axis=0)
        depth = np.sum([r.depth for r in results], axis=0)
        return coadd, depth
