"""Trackers: Hadoop-style task re-execution, speculation, and journaling.

MapReduce's scaling premise (paper §3): at thousands of nodes, failures are
the norm; the framework hides them by re-executing failed tasks and
launching redundant ("speculative") copies of stragglers.  That machinery is
what lets the coadd job survive node loss.

On a TPU pod the analogue is necessarily different — an SPMD program cannot
lose one participant mid-collective — so fault handling moves up a level:

* the *work decomposition* stays Hadoop-shaped: work is split into
  idempotent, journaled tasks whose outputs combine through a commutative
  monoid (coadd accumulation), so any task may be re-executed or executed
  twice without changing the result;
* task completion is journaled with a content digest; restart replays only
  missing tasks (checkpoint/restart at the job level);
* stragglers get speculative backups — first result wins, digests must
  agree (determinism check);
* retries distinguish transient from fatal errors (`faults.classify`):
  transient failures back off exponentially (capped) and re-execute, fatal
  ones — above all `DeterminismError` — escape immediately.

Two trackers share that contract:

* `JobTracker` — the original host-level API over explicit image-id shards
  (`MapTask`), kept for elastic repartition demos and its tests;
* `WindowTracker` — the streaming engine's fault domain (DESIGN.md §8):
  each `ScanWindow` of a windowed query is one task.  It owns retry,
  speculation, poison quarantine, and the window-partial journal the
  engine's resume path replays.
"""

from __future__ import annotations

import dataclasses
import hashlib
import statistics
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

import numpy as np

from repro.core.faults import (
    DeterminismError,
    PoisonedChunkError,
    QueryKilled,
    classify,
)


@dataclasses.dataclass
class MapTask:
    task_id: int
    image_ids: np.ndarray  # the shard of images this task maps

@dataclasses.dataclass
class TaskResult:
    task_id: int
    coadd: np.ndarray
    depth: np.ndarray
    digest: str
    attempts: int
    worker: int


def _digest(coadd: np.ndarray, depth: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(coadd, np.float32).tobytes())
    h.update(np.ascontiguousarray(depth, np.float32).tobytes())
    return h.hexdigest()[:16]


def partial_digest(parts) -> str:
    """Content digest of a window's partial-accumulator tuple.

    The idempotency token of a window task: speculation re-executes the
    window and demands digest agreement.  Materializes the partial to host
    (a sync) — which is why the tracker only digests when it must, never on
    the clean streaming path.
    """
    h = hashlib.sha256()
    for p in parts:
        h.update(np.ascontiguousarray(np.asarray(p)).tobytes())
    return h.hexdigest()[:16]


class FailureInjector:
    """Deterministic failure/straggler schedule for the legacy JobTracker.

    fail_plan: {(task_id, attempt): kind} with kind one of ``"fail"``
    (RuntimeError — transient by policy), ``"fail_transient"``/``"fail_os"``
    (other transient types; the retry net must catch them too), ``"fail_fatal"``
    (ValueError — must escape), or ``"slow"`` (sleep ``slow_s``).
    """

    _KINDS = {
        "fail": RuntimeError,
        "fail_transient": ConnectionError,
        "fail_os": OSError,
        "fail_fatal": ValueError,
    }

    def __init__(self, plan: Optional[Dict] = None, slow_s: float = 0.0):
        self.plan = plan or {}
        self.slow_s = slow_s

    def before_run(self, task_id: int, attempt: int):
        kind = self.plan.get((task_id, attempt))
        if kind is None:
            return
        if kind == "slow":
            if self.slow_s:
                time.sleep(self.slow_s)
            return
        exc = self._KINDS.get(kind)
        if exc is None:
            raise ValueError(f"unknown injection kind {kind!r}")
        raise exc(f"injected {kind}: task {task_id} attempt {attempt}")


class JobTracker:
    """Executes map tasks with journaling, retry, and speculative backup.

    ``executor(image_ids) -> (coadd, depth)`` must be deterministic in its
    inputs (ours is: jit'd pure function over seeded data), which the tracker
    *verifies* when speculation produces two results for one task.
    """

    def __init__(
        self,
        executor: Callable[[np.ndarray], tuple],
        n_workers: int = 4,
        max_attempts: int = 3,
        straggler_threshold_s: float = float("inf"),
        injector: Optional[FailureInjector] = None,
    ):
        self.executor = executor
        self.n_workers = n_workers
        self.max_attempts = max_attempts
        self.straggler_threshold_s = straggler_threshold_s
        self.injector = injector or FailureInjector()
        self.journal: Dict[int, TaskResult] = {}
        self.events: List[str] = []

    @staticmethod
    def split(image_ids: np.ndarray, n_tasks: int) -> List[MapTask]:
        """Location-free task partition (supports elastic re-partitioning)."""
        chunks = np.array_split(np.asarray(image_ids), n_tasks)
        return [MapTask(i, c) for i, c in enumerate(chunks) if len(c)]

    def _attempt(self, task: MapTask, attempt: int, worker: int) -> TaskResult:
        self.injector.before_run(task.task_id, attempt)
        t0 = time.perf_counter()
        coadd, depth = self.executor(task.image_ids)
        dt = time.perf_counter() - t0
        res = TaskResult(
            task.task_id, np.asarray(coadd), np.asarray(depth), "", attempt, worker
        )
        res.digest = _digest(res.coadd, res.depth)
        if dt > self.straggler_threshold_s:
            # Straggler: speculative backup on another worker; first-completed
            # semantics — here sequential, so verify digests agree instead.
            self.events.append(f"speculative task={task.task_id}")
            backup = self.executor(task.image_ids)
            bd = _digest(np.asarray(backup[0]), np.asarray(backup[1]))
            if bd != res.digest:
                raise DeterminismError(
                    f"nondeterministic task {task.task_id}: {res.digest} != {bd}"
                )
        return res

    def run(self, tasks: Sequence[MapTask]) -> tuple:
        """Run all tasks (skipping journaled ones), return combined coadd."""
        for ti, task in enumerate(tasks):
            if task.task_id in self.journal:
                self.events.append(f"journal-hit task={task.task_id}")
                continue
            worker = ti % self.n_workers
            for attempt in range(self.max_attempts):
                try:
                    res = self._attempt(task, attempt, worker)
                    self.journal[task.task_id] = res
                    break
                except Exception as e:  # noqa: PERF203
                    # Transient-vs-fatal split (faults.classify): only
                    # transient failures consume a retry; nondeterminism and
                    # other fatal errors escape — re-rolling them is wrong.
                    if classify(e) == "fatal":
                        raise
                    self.events.append(
                        f"retry task={task.task_id} attempt={attempt}: {e}"
                    )
                    worker = (worker + 1) % self.n_workers  # reschedule elsewhere
            else:
                raise RuntimeError(f"task {task.task_id} exhausted retries")
        # Commutative-monoid combine: order-independent.
        results = [self.journal[t.task_id] for t in tasks]
        coadd = np.sum([r.coadd for r in results], axis=0)
        depth = np.sum([r.depth for r in results], axis=0)
        return coadd, depth


# ----- streaming window fault domain (DESIGN.md §8) -----
@dataclasses.dataclass
class FaultCounters:
    """Per-query fault accounting, threaded into JobStats by the engine."""

    retries: int = 0              # failed attempts that were re-executed
    speculative_windows: int = 0  # straggler backups launched (and verified)
    quarantined_packs: int = 0    # packs gated out after persistent poison
    resumed_windows: int = 0      # journal hits replayed instead of re-run


def _block(parts):
    """Host-block on a partial tuple (speculation needs wall-clock truth)."""
    import jax

    return jax.block_until_ready(parts)


class WindowTracker:
    """Runs a window schedule as idempotent, journaled, retryable tasks.

    The streaming executors hand every `ScanWindow` through here (when
    ``on_fault != "raise"``); the tracker owns the fault policy, the engine
    owns the device work via two callbacks:

    * ``acquire(win, quarantined) -> operands`` — make the window's chunk
      resident (the upload seam; raises on injected/real upload failures and
      on poison detection);
    * ``dispatch(operands, win, quarantined) -> partials`` — issue the
      window's jitted scan (async; the partial tuple stays on device).

    Clean-path cost is one dict lookup + one journal insert per window: no
    digests, no syncs, no timing — the one-sync-at-reduce-time contract
    (DESIGN.md §6) and the ≤1.1× BENCH overhead gate both survive.  Enabling
    speculation (``straggler_factor``) is the documented exception: timing a
    window means blocking on it, so wall clock degrades to sum-of-windows in
    exchange for straggler detection.
    """

    def __init__(
        self,
        policy: str = "retry",
        max_attempts: int = 3,
        backoff_s: float = 0.05,
        backoff_cap_s: float = 2.0,
        straggler_factor: Optional[float] = None,
        straggler_min_windows: int = 2,
        injector=None,
        sleep: Callable[[float], None] = time.sleep,
        quarantined: Optional[Iterable[int]] = None,
        concurrent_speculation: bool = True,
    ):
        if policy not in ("retry", "quarantine", "raise"):
            raise ValueError(
                f"policy must be 'retry', 'quarantine', or 'raise'; got {policy!r}"
            )
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self.policy = policy
        self.max_attempts = max_attempts
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self.straggler_factor = straggler_factor
        self.straggler_min_windows = straggler_min_windows
        self.injector = injector
        self._sleep = sleep
        # Concurrent speculation (§8): straggler backups run on a worker
        # thread so the main loop proceeds to the next window while the
        # backup re-executes; digest agreement is checked when the backups
        # drain at the end of the run.  False restores the serialized
        # inline backup (the PR 6 behavior).
        self.concurrent_speculation = concurrent_speculation
        self.counters = FaultCounters()
        self.events: List[str] = []
        self.durations: List[float] = []
        # Pre-quarantined packs (e.g. the engine's persistent registry,
        # released only by `ResidencyManager.reverify_quarantined`): they
        # gate out from window zero and report as uncovered, but only
        # *fresh* quarantines count in ``counters.quarantined_packs``.
        self.quarantined: Set[int] = set(quarantined or ())
        self._backups: List[Dict] = []

    def _backoff(self, attempt: int) -> None:
        self._sleep(min(self.backoff_s * (2 ** (attempt - 1)), self.backoff_cap_s))

    def run(self, windows, acquire, dispatch, journal: Dict) -> tuple:
        """Execute ``windows``; return ``(partials, sorted quarantined packs)``.

        ``journal`` maps ``win.key -> partial tuple`` and belongs to the
        caller: completed windows are written through as they finish (each
        commit deferred one window so it overlaps the successor's compute),
        and a `QueryKilled` (or any fatal error) still leaves every
        finished window journaled — a rerun with the same journal replays
        only the missing ones (``resumed_windows`` counts the hits).
        """
        acc = None
        prefetched: Dict = {}
        pending = None  # (win, part) committed once the next window is live

        def flush(seam: bool) -> None:
            # Commit the held partial.  ``seam`` gates the injector's
            # kill-after-journaling hook: on the unwind path a fatal is
            # already in flight, so only the journal write happens.
            nonlocal pending
            if pending is None:
                return
            pwin, ppart = pending
            pending = None
            journal[pwin.key] = ppart
            if seam and self.injector is not None:
                # After journaling: an injected kill loses no finished work.
                self.injector.on_window_complete(pwin)

        try:
            try:
                for i, win in enumerate(windows):
                    key = win.key
                    if key in journal:
                        flush(True)
                        part = journal[key]
                        self.counters.resumed_windows += 1
                        self.events.append(f"journal-hit window={key}")
                        self._prefetch(i, windows, journal, acquire,
                                       prefetched)
                    else:
                        part = self._run_window(
                            win, acquire, dispatch, prefetched.pop(key, None)
                        )
                        # Software pipeline: start the next chunk's upload,
                        # THEN commit the previous window — a disk journal's
                        # host sync now overlaps this window's in-flight
                        # compute instead of serializing the stream.  This
                        # window's own commit waits until the next one is
                        # dispatched (or the loop/unwind flush below).
                        self._prefetch(i, windows, journal, acquire,
                                       prefetched)
                        flush(True)
                        pending = (win, part)
                    acc = part if acc is None else tuple(
                        a + b for a, b in zip(acc, part)
                    )
                flush(True)
            finally:
                # A fatal above must not lose a finished-but-uncommitted
                # window: the resume contract is that every completed
                # window is journaled when the query dies.
                flush(False)
        finally:
            # Join in-flight backups even when a fatal error escapes: their
            # threads read shared engine state and must retire first.
            backups, self._backups = self._backups, []
            for rec in backups:
                rec["thread"].join()
        self._verify_backups(backups)
        return acc, sorted(self.quarantined)

    def _prefetch(self, i, windows, journal, acquire, prefetched) -> None:
        """Double buffer: start the next chunk's async upload now.

        The operands are carried so the window doesn't re-acquire; the
        prefetch is opportunistic — a failure surfaces when the window
        itself runs (fatal errors re-raise there too), though a consumed
        transient attempt still counts as a retry.
        """
        if i + 1 >= len(windows) or windows[i + 1].key in journal:
            return
        nxt = windows[i + 1]
        try:
            prefetched[nxt.key] = acquire(nxt, frozenset(self.quarantined))
        except Exception as e:
            if classify(e) == "transient":
                self.counters.retries += 1
            self.events.append(f"prefetch-fault window={nxt.key}: {e}")

    def _verify_backups(self, backups: List[Dict]) -> None:
        """Enforce digest agreement for drained concurrent backups.

        A backup that failed transiently gets one inline re-execution (its
        purpose is the determinism proof, so it must actually produce a
        digest); fatal errors — and disagreement — escape as ever.
        """
        for rec in backups:
            err = rec.get("error")
            if err is not None:
                if classify(err) == "fatal":
                    raise err
                self.counters.retries += 1
                self.events.append(
                    f"backup-retry window={rec['win'].key}: {err}"
                )
                backup = _block(
                    rec["dispatch"](rec["ops"], rec["win"], rec["drop"])
                )
                rec["digest"] = partial_digest(backup)
            if rec["digest"] != rec["primary_digest"]:
                raise DeterminismError(
                    f"window {rec['win'].key}: primary digest "
                    f"{rec['primary_digest']} != backup {rec['digest']}"
                )

    def _run_window(self, win, acquire, dispatch, ops=None):
        attempt = 0
        while True:
            attempt += 1
            try:
                if ops is None:
                    ops = acquire(win, frozenset(self.quarantined))
                t0 = time.perf_counter()
                if self.injector is not None:
                    self.injector.on_window_execute(win)  # straggler seam
                part = dispatch(ops, win, frozenset(self.quarantined))
                if self.straggler_factor is not None:
                    part = _block(part)
                    dt = time.perf_counter() - t0
                    self._maybe_speculate(win, ops, dispatch, part, dt)
                    self.durations.append(dt)
                return part
            except QueryKilled:
                raise
            except PoisonedChunkError as e:
                ops = None  # re-acquire: the staged chunk was rejected
                self.counters.retries += 1
                self.events.append(
                    f"poison window={win.key} attempt={attempt}: {e}"
                )
                if attempt < self.max_attempts:
                    self._backoff(attempt)
                    continue
                if self.policy == "quarantine":
                    fresh = set(e.packs) - self.quarantined
                    if not fresh:
                        # Quarantining can't make progress: the chunk fails
                        # verification on packs already gated out.
                        raise
                    self.quarantined |= fresh
                    self.counters.quarantined_packs += len(fresh)
                    self.events.append(f"quarantine packs={sorted(fresh)}")
                    attempt = 0  # the sanitized chunk gets fresh attempts
                    continue
                raise
            except Exception as e:
                if classify(e) == "fatal":
                    raise
                ops = None  # re-acquire on retry (a hit if the chunk landed)
                self.counters.retries += 1
                self.events.append(
                    f"retry window={win.key} attempt={attempt}: {e}"
                )
                if attempt >= self.max_attempts:
                    raise
                self._backoff(attempt)

    def _maybe_speculate(self, win, ops, dispatch, part, dt: float) -> None:
        if len(self.durations) < self.straggler_min_windows:
            return
        median = statistics.median(self.durations)
        if median <= 0 or dt <= self.straggler_factor * median:
            return
        # Straggler: launch a backup execution of the same window.  First
        # result wins (the primary already finished); the backup exists to
        # prove the task is re-executable — digests must agree.
        self.counters.speculative_windows += 1
        self.events.append(
            f"speculative window={win.key} dt={dt:.4f}s median={median:.4f}s"
        )
        drop = frozenset(self.quarantined)
        if not self.concurrent_speculation:
            backup = _block(dispatch(ops, win, drop))
            d0, d1 = partial_digest(part), partial_digest(backup)
            if d0 != d1:
                raise DeterminismError(
                    f"window {win.key}: primary digest {d0} != backup {d1}"
                )
            return
        # Concurrent: the backup dispatch runs on a worker thread while the
        # main loop moves on to later windows — a slow primary no longer
        # serializes its own backup.  Digest agreement is enforced when the
        # run drains (`_verify_backups`); the digest of the primary is taken
        # now, while ``part`` is known-final.
        rec: Dict = {
            "win": win, "ops": ops, "dispatch": dispatch, "drop": drop,
            "primary_digest": partial_digest(part), "digest": None,
        }

        def _backup() -> None:
            try:
                rec["digest"] = partial_digest(_block(dispatch(ops, win, drop)))
            except BaseException as e:  # joined + reclassified at drain
                rec["error"] = e

        rec["thread"] = threading.Thread(
            target=_backup, name=f"backup-{win.key}", daemon=True
        )
        self._backups.append(rec)
        rec["thread"].start()


# ----- brick materialization as tracked tasks (DESIGN.md §9) -----
@dataclasses.dataclass
class BrickTask:
    """One (brick, band) cell of a materialization job, with its outcome."""

    band: str
    row: int
    col: int
    status: str = "pending"   # pending | done | partial | skipped
    attempts: int = 0
    packs_scanned: int = 0
    retries: int = 0          # window-level retries inside the brick's query
    resumed_windows: int = 0  # journal replays (a resumed killed brick)


@dataclasses.dataclass
class MaterializeReport:
    """What a `materialize_bricks` call did, per task and in aggregate."""

    tasks: List[BrickTask]

    @property
    def completed(self) -> int:
        return sum(t.status in ("done", "partial") for t in self.tasks)

    @property
    def skipped(self) -> int:
        return sum(t.status == "skipped" for t in self.tasks)

    @property
    def partial_bricks(self) -> int:
        return sum(t.status == "partial" for t in self.tasks)


class MaterializeTracker:
    """Drives brick materialization as journaled, retryable tasks.

    The brick-level sibling of `WindowTracker`: each (brick, band) cell is
    one idempotent task (its output lands in the `BrickStore`, which doubles
    as the completion journal — ``is_done`` consults it, so a killed job
    resumes by skipping finished bricks).  Transient faults that escape the
    window-level retry net consume brick-level attempts with the same
    capped backoff; fatal faults — above all `QueryKilled` — escape
    immediately, leaving the store and the in-flight brick's window journal
    in place for the resume.
    """

    def __init__(
        self,
        max_attempts: int = 3,
        backoff_s: float = 0.05,
        backoff_cap_s: float = 2.0,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.max_attempts = max(max_attempts, 1)
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self._sleep = sleep
        self.events: List[str] = []

    def run(
        self,
        tasks: Sequence[BrickTask],
        is_done: Callable[[BrickTask], bool],
        run_one: Callable[[BrickTask], None],
    ) -> List[BrickTask]:
        tasks = list(tasks)
        for task in tasks:
            if is_done(task):
                task.status = "skipped"
                self.events.append(
                    f"journal-hit brick=({task.band},{task.row},{task.col})"
                )
                continue
            attempt = 0
            while True:
                attempt += 1
                task.attempts = attempt
                try:
                    run_one(task)
                    break
                except Exception as e:  # noqa: PERF203
                    if classify(e) == "fatal":
                        raise
                    self.events.append(
                        f"retry brick=({task.band},{task.row},{task.col}) "
                        f"attempt={attempt}: {e}"
                    )
                    if attempt >= self.max_attempts:
                        raise
                    self._sleep(
                        min(self.backoff_s * (2 ** (attempt - 1)),
                            self.backoff_cap_s)
                    )
        return tasks


__all__ = [
    "BrickTask",
    "FailureInjector",
    "FaultCounters",
    "JobTracker",
    "MapTask",
    "MaterializeReport",
    "MaterializeTracker",
    "TaskResult",
    "WindowTracker",
    "partial_digest",
]
