"""Block composition: pre-norm residual blocks for every assigned family.

Block types:
  * ``attn_mlp``  — self-attention + MLP (dense / the shared Zamba block)
  * ``moe``       — self-attention + MoE MLP
  * ``cross``     — cross-attention (+MLP) for VLM / enc-dec decoder
  * ``mamba``     — Mamba-2 SSD block (single residual)

Each has init / apply / decode variants operating on one layer's params;
stacking and scanning lives in `model.py`.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp_apply, mlp_init, rmsnorm, rmsnorm_init


# ------------------------------------------------------------- attn + mlp ---
def attn_mlp_init(key, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": rmsnorm_init(cfg.d_model),
        "attn": attn.attn_init(k1, cfg),
        "ln_mlp": rmsnorm_init(cfg.d_model),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }


def attn_mlp_apply(params, x, cfg: ModelConfig, positions=None, causal=True,
                   return_kv=False):
    res = attn.attend_full(params["attn"], rmsnorm(params["ln_attn"], x), cfg,
                           positions=positions, causal=causal, return_kv=return_kv)
    h, kv = res if return_kv else (res, None)
    x = x + h
    h = mlp_apply(params["mlp"], rmsnorm(params["ln_mlp"], x), cfg.mlp_type)
    x = x + h
    return (x, kv) if return_kv else x


def attn_mlp_decode(params, x, cache, pos, cfg: ModelConfig):
    h, cache = attn.attend_decode(
        params["attn"], rmsnorm(params["ln_attn"], x), cache, pos, cfg
    )
    x = x + h
    h = mlp_apply(params["mlp"], rmsnorm(params["ln_mlp"], x), cfg.mlp_type)
    return x + h, cache


# ------------------------------------------------------------------- moe ---
def moe_block_init(key, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": rmsnorm_init(cfg.d_model),
        "attn": attn.attn_init(k1, cfg),
        "ln_mlp": rmsnorm_init(cfg.d_model),
        "moe": moe_mod.moe_init(k2, cfg),
    }


def moe_block_apply(params, x, cfg: ModelConfig, positions=None, return_kv=False):
    res = attn.attend_full(params["attn"], rmsnorm(params["ln_attn"], x), cfg,
                           positions=positions, causal=True, return_kv=return_kv)
    h, kv = res if return_kv else (res, None)
    x = x + h
    h, aux = moe_mod.moe_apply(params["moe"], rmsnorm(params["ln_mlp"], x), cfg)
    x = x + h
    return (x, aux, kv) if return_kv else (x, aux)


def moe_block_decode(params, x, cache, pos, cfg: ModelConfig):
    h, cache = attn.attend_decode(
        params["attn"], rmsnorm(params["ln_attn"], x), cache, pos, cfg
    )
    x = x + h
    h = moe_mod.moe_apply_decode(params["moe"], rmsnorm(params["ln_mlp"], x), cfg)
    return x + h, cache


# ------------------------------------------------- cross-attention blocks ---
def cross_block_init(key, cfg: ModelConfig, with_mlp: bool = True) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln_x": rmsnorm_init(cfg.d_model),
        "cross": attn.cross_attn_init(k1, cfg),
    }
    if with_mlp:
        p["ln_mlp"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type)
    return p


def cross_block_apply(params, x, context, cfg: ModelConfig):
    h = attn.attend_cross(params["cross"], rmsnorm(params["ln_x"], x), context, cfg)
    x = x + h
    if "mlp" in params:
        h = mlp_apply(params["mlp"], rmsnorm(params["ln_mlp"], x), cfg.mlp_type)
        x = x + h
    return x


def cross_block_decode_cached(params, x, ck, cv, cfg: ModelConfig):
    """Cross-attn with precomputed context K/V (B, T, Hkv, Dh)."""
    import numpy as np

    dt = x.dtype
    b, s, _ = x.shape
    dh = cfg.head_dim
    from repro.models.layers import cast

    xq = rmsnorm(params["ln_x"], x)
    q = (xq @ cast(params["cross"]["w_q"], dt)).reshape(b, s, cfg.n_heads, dh)
    if cfg.qkv_bias:
        q = q + cast(params["cross"]["b_q"], dt).reshape(cfg.n_heads, dh)
    logits = attn._gqa_scores(q, ck) / np.sqrt(dh)
    p = jax.nn.softmax(logits, axis=-1)
    o = attn._gqa_out(p, cv, b, s, cfg.n_heads, dh)
    x = x + o @ cast(params["cross"]["w_o"], dt)
    if "mlp" in params:
        h = mlp_apply(params["mlp"], rmsnorm(params["ln_mlp"], x), cfg.mlp_type)
        x = x + h
    return x


def cross_context_kv(params, context, cfg: ModelConfig):
    """Precompute cross-attn K/V from context (prefill-time, cached)."""
    from repro.models.layers import cast

    dt = context.dtype
    b, t, _ = context.shape
    dh = cfg.head_dim
    k = (context @ cast(params["cross"]["w_k"], dt)).reshape(b, t, cfg.n_kv_heads, dh)
    v = (context @ cast(params["cross"]["w_v"], dt)).reshape(b, t, cfg.n_kv_heads, dh)
    if cfg.qkv_bias:
        k = k + cast(params["cross"]["b_k"], dt).reshape(cfg.n_kv_heads, dh)
        v = v + cast(params["cross"]["b_v"], dt).reshape(cfg.n_kv_heads, dh)
    return k, v


# ----------------------------------------------------------------- mamba ---
def mamba_block_init(key, cfg: ModelConfig) -> Dict:
    return {
        "ln": rmsnorm_init(cfg.d_model),
        "mamba": ssm_mod.mamba2_init(key, cfg),
    }


def mamba_block_apply(params, x, cfg: ModelConfig, return_state=False):
    if return_state:
        h, st = ssm_mod.mamba2_apply(
            params["mamba"], rmsnorm(params["ln"], x), cfg, return_state=True
        )
        return x + h, st
    return x + ssm_mod.mamba2_apply(params["mamba"], rmsnorm(params["ln"], x), cfg)


def mamba_block_decode(params, x, state, cfg: ModelConfig):
    h, state = ssm_mod.mamba2_decode(params["mamba"], rmsnorm(params["ln"], x), state, cfg)
    return x + h, state
