"""Mamba-2 (SSD) block: chunked state-space duality, train + decode paths.

Faithful structure (arXiv:2405.21060): fused in_proj -> [z | x | B | C | dt],
depthwise causal conv over [x|B|C], SiLU, SSD with scalar-identity A per
head, D skip, SiLU(z) gating, RMSNorm, out_proj.

Training path = chunked SSD, vectorized over chunks: quadratic work inside
length-L chunks (dense einsums) and an O(log n_chunks) associative scan for
the inter-chunk state carry — the same decomposition the Pallas kernel
(`repro.kernels.ssd`) implements with a sequential VMEM-resident state; the
associative-scan form lowers to a small HLO, which matters for the 512-way
dry-run compile budget.

Decode path = the raw recurrence: state (B, H, N, P) and a (W-1)-deep conv
ring buffer advance one token per step.  This is what makes the SSM archs
the only ones eligible for ``long_500k`` (state is O(1) in sequence).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import cast, rmsnorm, rmsnorm_init


def mamba2_init(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.n_ssm_heads
    conv_ch = di + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    return {
        "in_proj": jax.random.normal(k1, (d, 2 * di + 2 * n + h), jnp.float32) * s,
        "conv_w": jax.random.normal(k2, (cfg.conv_width, conv_ch), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(di),
        "out_proj": jax.random.normal(k3, (di, d), jnp.float32) / np.sqrt(di),
    }


def _split_proj(proj, cfg: ModelConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * n]
    dt = proj[..., di + di + 2 * n :]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, width W. xbc: (B,S,C); w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):  # small static unroll (W=4)
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return out + b


def _ssd_chunked(log_a, Bm, Cm, x, chunk: int, return_state: bool = False,
                 intra_dtype: str = "float32"):
    """Chunked SSD.

    log_a: (B,S,H) log-decay (<= 0) — passed in log space because the decay
    itself underflows f32 for large dt*|A| and log(0) poisons gradients.
    Bm/Cm: (B,S,N); x: (B,S,H,P).
    """
    b, s, h = log_a.shape
    n = Bm.shape[-1]
    p = x.shape[-1]
    l = min(chunk, s)
    s_orig = s
    if s % l:
        # Pad with identity steps: log_a=0 (no decay), B=C=x=0 — the state
        # is unchanged and padded outputs are sliced off below.
        pad = l - s % l
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // l

    Br = Bm.reshape(b, nc, l, n)
    Cr = Cm.reshape(b, nc, l, n)
    xr = x.reshape(b, nc, l, h, p)

    log_a = log_a.reshape(b, nc, l, h).astype(jnp.float32)
    cum = jnp.cumsum(log_a, axis=2)                       # (B,nc,L,H) inclusive
    # Intra-chunk: masked decay matrix per head.
    li = cum[:, :, :, None, :]                            # (B,nc,L,1,H)
    lj = cum[:, :, None, :, :]                            # (B,nc,1,L,H)
    ii = jnp.arange(l)[:, None]
    jj = jnp.arange(l)[None, :]
    causal = (jj <= ii)[None, None, :, :, None]
    # Mask BEFORE exp: for j > i the exponent is positive and can overflow,
    # and a where() around an inf poisons gradients.
    diff = jnp.where(causal, li - lj, 0.0)
    idt = jnp.dtype(intra_dtype)
    # Intra-chunk quadratic work in ``intra_dtype`` (§Perf C2: the L x L
    # decay/score tensors dominate HBM traffic; bf16 halves it).  Decay
    # cumsums stay fp32; only the bounded [0,1] decay matrix is downcast.
    m = jnp.where(causal, jnp.exp(diff), 0.0).astype(idt)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr.astype(idt), Br.astype(idt))
    g = cb[..., None] * m                                  # (B,nc,L,L,H)
    y_intra = jnp.einsum(
        "bcijh,bcjhp->bcihp", g, xr.astype(idt)
    ).astype(jnp.float32)

    # Chunk summaries for the carried state.
    w_last = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,nc,L,H)
    t_sum = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchnp", Br.astype(jnp.float32), w_last, xr.astype(jnp.float32)
    )                                                      # (B,nc,H,N,P)
    decay = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)

    def combine(left, right):
        d1, s1 = left
        d2, s2 = right
        return d1 * d2, s1 * d2[..., None, None] + s2

    d_inc, s_inc = jax.lax.associative_scan(combine, (decay, t_sum), axis=1)
    # Incoming state of chunk c = inclusive state of chunk c-1 (shifted).
    s_in = jnp.concatenate([jnp.zeros_like(s_inc[:, :1]), s_inc[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcin,bchnp->bcihp", Cr.astype(jnp.float32), s_in)
    y_inter = y_inter * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    if return_state:
        return y, s_inc[:, -1]  # (B,H,N,P): state after the last token
    return y


def mamba2_apply(params, u, cfg: ModelConfig, return_state: bool = False):  # noqa: C901
    """u: (B, S, D) -> (B, S, D). Training / prefill path.

    With ``return_state`` also returns {"conv", "ssm"} — the states a decode
    loop would hold after consuming the sequence (prefill -> decode handoff).
    """
    dt_ = u.dtype
    b, s, d = u.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    proj = u @ cast(params["in_proj"], dt_)
    z, xbc_raw, dtv = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc_raw, cast(params["conv_w"], dt_), cast(params["conv_b"], dt_))
    xbc = jax.nn.silu(xbc)
    xh = xbc[..., :di].reshape(b, s, h, p)
    Bm = xbc[..., di : di + n]
    Cm = xbc[..., di + n :]
    dt_act = jax.nn.softplus(dtv.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    log_a = -jnp.exp(params["A_log"]) * dt_act            # (B,S,H), <= 0
    res = _ssd_chunked(
        log_a, Bm, Cm, xh * dt_act[..., None].astype(dt_), cfg.ssm_chunk,
        return_state=return_state, intra_dtype=cfg.ssd_intra_dtype,
    )
    y, s_final = res if return_state else (res, None)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y)
    out = y @ cast(params["out_proj"], dt_)
    if return_state:
        w = cfg.conv_width
        conv_state = xbc_raw[:, s - (w - 1):, :] if s >= w - 1 else jnp.pad(
            xbc_raw, ((0, 0), (w - 1 - s, 0), (0, 0))
        )
        return out, {"conv": conv_state, "ssm": s_final}
    return out


def init_ssm_state(cfg: ModelConfig, batch: int, dtype: str) -> Dict:
    di, n = cfg.d_inner, cfg.ssm_state
    h, p = cfg.n_ssm_heads, cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), jnp.dtype(dtype)),
        "ssm": jnp.zeros((batch, h, n, p), jnp.float32),
    }


def mamba2_decode(params, u, state: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """u: (B, 1, D); advances conv ring buffer + SSM state one token."""
    dt_ = u.dtype
    b = u.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    proj = u @ cast(params["in_proj"], dt_)               # (B,1,*)
    z, xbc, dtv = _split_proj(proj, cfg)
    # Conv over [state || new token].
    hist = jnp.concatenate([state["conv"], xbc], axis=1)  # (B,W,C)
    w = cast(params["conv_w"], dt_)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + cast(params["conv_b"], dt_)
    new_conv = hist[:, 1:, :]
    xbc1 = jax.nn.silu(conv_out)[:, None, :]              # (B,1,C)
    xh = xbc1[..., :di].reshape(b, h, p)
    Bm = xbc1[..., di : di + n].reshape(b, n)
    Cm = xbc1[..., di + n :].reshape(b, n)
    dt_act = jax.nn.softplus(dtv[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(params["A_log"]) * dt_act)       # (B,H)
    xw = xh.astype(jnp.float32) * dt_act[..., None]
    S = state["ssm"] * a[..., None, None] + jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32), xw)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), S)
    y = y + params["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y)
    out = y @ cast(params["out_proj"], dt_)
    return out, {"conv": new_conv, "ssm": S}
