"""Attention: MHA/GQA/MQA with RoPE, sliding window, KV cache, cross-attn.

Three entry points:
  * ``attend_full``   — training / prefill self-attention (causal or not).
  * ``attend_decode`` — one-step decode against a (possibly model-axis-
                        sharded) KV cache; masking by position.
  * ``attend_cross``  — decoder->encoder / text->image cross attention.

The XLA path keeps logits in fp32 and relies on GSPMD to shard the einsums;
`repro.kernels.attention` provides the Pallas flash path for real TPUs
(wired via ``use_flash`` in apply-time options).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, cast

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, d_model: Optional[int] = None) -> Dict:
    d = d_model or cfg.d_model
    dh = cfg.head_dim
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(d)
    p = {
        "w_q": jax.random.normal(kq, (d, cfg.n_heads * dh), jnp.float32) * s,
        "w_k": jax.random.normal(kk, (d, cfg.n_kv_heads * dh), jnp.float32) * s,
        "w_v": jax.random.normal(kv, (d, cfg.n_kv_heads * dh), jnp.float32) * s,
        "w_o": jax.random.normal(ko, (cfg.n_heads * dh, d), jnp.float32)
        / np.sqrt(cfg.n_heads * dh),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((cfg.n_heads * dh,), jnp.float32)
        p["b_k"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.float32)
        p["b_v"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.float32)
    return p


def _project_qkv(params, x, cfg: ModelConfig):
    dt = x.dtype
    b, s, _ = x.shape
    dh = cfg.head_dim
    q = x @ cast(params["w_q"], dt)
    k = x @ cast(params["w_k"], dt)
    v = x @ cast(params["w_v"], dt)
    if cfg.qkv_bias:
        q = q + cast(params["b_q"], dt)
        k = k + cast(params["b_k"], dt)
        v = v + cast(params["b_v"], dt)
    q = q.reshape(b, s, cfg.n_heads, dh)
    k = k.reshape(b, s, cfg.n_kv_heads, dh)
    v = v.reshape(b, s, cfg.n_kv_heads, dh)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,S,Hq,D), k: (B,T,Hkv,D) -> logits (B,Hkv,G,S,T) grouped."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)


def _gqa_out(p, v, b, s, hq, d):
    hkv = v.shape[2]
    g = hq // hkv
    o = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return o.reshape(b, s, hq * d)


# Above this sequence length, the XLA path switches to the chunked
# (flash-style, online-softmax) formulation so S x S logits never
# materialize.  Tunable per-run (hillclimb knob).
CHUNKED_ATTN_THRESHOLD = 2048
Q_BLOCK = 512
KV_BLOCK = 1024


def _attend_chunked(q, k, v, cfg: ModelConfig, causal: bool):
    """Flash-style attention in pure jnp: double scan over q/kv blocks with
    online softmax.  Positions are assumed to be arange(S) (all callers).

    q: (B,S,Hq,D); k/v: (B,S,Hkv,D) -> (B,S,Hq,D) in q.dtype.
    Both scan bodies are rematted so the backward pass recomputes block
    logits instead of storing them (the flash backward tradeoff).
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qb = min(Q_BLOCK, s)
    kb = min(KV_BLOCK, s)
    assert s % qb == 0 and s % kb == 0, (s, qb, kb)
    nq, nk = s // qb, s // kb
    scale = 1.0 / np.sqrt(d)

    qg = q.reshape(b, nq, qb, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, kb, hkv, d).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kb, hkv, d).transpose(1, 0, 2, 3, 4)
    window = cfg.sliding_window

    def kv_step(carry, xs):
        acc, m, l, q_blk, qi = carry
        k_blk, v_blk, kj = xs
        logits = (
            jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk).astype(jnp.float32) * scale
        )  # (B,Hkv,G,qb,kb)
        qpos = qi * qb + jnp.arange(qb)[:, None]
        kpos = kj * kb + jnp.arange(kb)[None, :]
        mask = jnp.ones((qb, kb), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqt,btkd->bkgqd", p.astype(v_blk.dtype), v_blk
        ).astype(jnp.float32)
        return (acc, m_new, l, q_blk, qi), None

    def q_step(_, xs):
        q_blk, qi = xs
        acc0 = jnp.zeros((b, hkv, g, qb, d), jnp.float32)
        m0 = jnp.full((b, hkv, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qb), jnp.float32)
        (acc, m, l, _, _), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, l0, q_blk, qi),
            (kr, vr, jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B,Hkv,G,qb,D) -> (B,qb,Hq,D)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qb, hq, d)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None, (qg, jnp.arange(nq)))
    # (nq, B, qb, Hq, D) -> (B, S, Hq, D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, hq, d)


def attend_full(
    params,
    x,
    cfg: ModelConfig,
    positions=None,
    causal: bool = True,
    use_rope: bool = True,
    return_kv: bool = False,
):
    """Self-attention over full sequences (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if use_rope and cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if s >= CHUNKED_ATTN_THRESHOLD and not cfg.force_dense_attn:
        o = _attend_chunked(q, k, v, cfg, causal).reshape(b, s, cfg.n_heads * cfg.head_dim)
    else:
        scale = 1.0 / np.sqrt(cfg.head_dim)
        logits = _gqa_scores(q, k) * scale  # (B,Hkv,G,S,T)
        qi = positions[:, None, None, :, None]
        ki = positions[:, None, None, None, :]
        mask = jnp.ones((b, 1, 1, s, s), bool)
        if causal:
            mask &= ki <= qi
        if cfg.sliding_window is not None:
            mask &= ki > qi - cfg.sliding_window
        logits = jnp.where(mask, logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        o = _gqa_out(p, v, b, s, cfg.n_heads, cfg.head_dim)
    out = o @ cast(params["w_o"], x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype: str):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, jnp.dtype(dtype)),
        "v": jnp.zeros(shape, jnp.dtype(dtype)),
    }


def attend_decode(
    params,
    x,            # (B, 1, D)
    cache: Dict,  # {"k","v"}: (B, T, Hkv, Dh)
    pos,          # scalar int32 — current position
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode: update cache at ``pos``, attend over prefix."""
    b = x.shape[0]
    dh = cfg.head_dim
    q, k_new, v_new = _project_qkv(params, x, cfg)
    posb = jnp.full((b, 1), pos, jnp.int32)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, posb, cfg.rope_theta)
        k_new = apply_rope(k_new, posb, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    t = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    logits = _gqa_scores(q, k) * scale  # (B,Hkv,G,1,T)
    ki = jnp.arange(t)[None, None, None, None, :]
    mask = ki <= pos
    if cfg.sliding_window is not None:
        mask &= ki > pos - cfg.sliding_window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = _gqa_out(p, v, b, 1, cfg.n_heads, dh)
    out = o @ cast(params["w_o"], x.dtype)
    return out, {"k": k, "v": v}


def cross_attn_init(key, cfg: ModelConfig) -> Dict:
    return attn_init(key, cfg)


def attend_cross(params, x, context, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B,S,D) queries; context: (B,T,D) keys/values (no masking)."""
    dt = x.dtype
    b, s, _ = x.shape
    t = context.shape[1]
    dh = cfg.head_dim
    q = (x @ cast(params["w_q"], dt)).reshape(b, s, cfg.n_heads, dh)
    k = (context @ cast(params["w_k"], dt)).reshape(b, t, cfg.n_kv_heads, dh)
    v = (context @ cast(params["w_v"], dt)).reshape(b, t, cfg.n_kv_heads, dh)
    if cfg.qkv_bias:
        q = q + cast(params["b_q"], dt).reshape(cfg.n_heads, dh)
        k = k + cast(params["b_k"], dt).reshape(cfg.n_kv_heads, dh)
        v = v + cast(params["b_v"], dt).reshape(cfg.n_kv_heads, dh)
    scale = 1.0 / np.sqrt(dh)
    logits = _gqa_scores(q, k) * scale
    p = jax.nn.softmax(logits, axis=-1)
    o = _gqa_out(p, v, b, s, cfg.n_heads, dh)
    return o @ cast(params["w_o"], dt)
