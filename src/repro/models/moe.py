"""Mixture-of-Experts MLP: top-k routing with GShard-style capacity dispatch.

Train/prefill path: tokens are grouped by batch row; each group dispatches
its tokens into per-expert capacity buffers via one-hot einsums (static
shapes — the TPU/pjit-native formulation; GSPMD turns the expert einsums
into sharded GEMMs + all-to-alls when the expert/ff dims are sharded).
Tokens beyond capacity are dropped (standard GShard semantics); capacity
factor is configurable per run.

Decode path: one-token batches make capacity dispatch degenerate, so decode
computes a dense mixture over the top-k experts' weights — at decode the
layer is weight-bandwidth-bound anyway, and every expert page is touched
once per batch (the vLLM-style argument).

Aux loss: Switch-style load-balancing loss, returned to the trainer.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import cast


def _shard_batch(x, cfg: ModelConfig):
    """Pin dim 0 to the mesh's data axes (GSPMD otherwise replicates the
    scatter buffers and inserts full-size all-reduces — §Perf A2)."""
    if not cfg.act_shard_axes:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(tuple(cfg.act_shard_axes), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def moe_init(key, cfg: ModelConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(f)
    return {
        "router": jax.random.normal(kr, (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(kg, (e, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ku, (e, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(kd, (e, f, d), jnp.float32) * s_out,
    }


def _expert_ffn(params, h, dt):
    """h: (B, E, C, D) -> (B, E, C, D) through per-expert SwiGLU."""
    g = jnp.einsum("becd,edf->becf", h, cast(params["w_gate"], dt))
    u = jnp.einsum("becd,edf->becf", h, cast(params["w_up"], dt))
    a = jax.nn.silu(g) * u
    return jnp.einsum("becf,efd->becd", a, cast(params["w_down"], dt))


def moe_apply(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss). Dispatch impl selected by cfg.moe_impl."""
    if cfg.moe_impl == "shard_map":
        return moe_apply_shardmap(params, x, cfg)
    if cfg.moe_impl == "scatter":
        return moe_apply_scatter(params, x, cfg)
    return moe_apply_onehot(params, x, cfg)


def moe_apply_shardmap(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit-locality MoE (§Perf A4): the paper's move-compute-to-the-data
    stance expressed directly.

    The routed FFN is token-local given replicated expert weights, so we
    `shard_map` it over every mesh axis the batch divides: tokens never move,
    experts are replicated (they are small), the dispatch is the scatter
    formulation executed device-locally, and the ONLY collectives left are
    the expert-weight gradient psums the backward pass inserts.  GSPMD's
    auto-partitioner (onehot/scatter paths) instead reshards the expanded
    (E*C) buffers through 35 GB/layer all-reduces — explicit beats implicit
    at this granularity.
    """
    if not cfg.act_shard_axes:
        return moe_apply_scatter(params, x, cfg)
    from jax.sharding import PartitionSpec as P

    b = x.shape[0]
    axes = tuple(cfg.act_shard_axes)
    local_cfg = dataclasses.replace(cfg, act_shard_axes=())

    def body(p, xl):
        out, aux = moe_apply_scatter(p, xl, local_cfg)
        for ax in axes:
            aux = jax.lax.pmean(aux, ax)
        return out, aux

    from repro.distributed.sharding import shard_map_compat

    fn = shard_map_compat(
        body,
        in_specs=(P(), P(axes, None, None)),
        out_specs=(P(axes, None, None), P()),
        check=False,
    )
    return fn(params, x)


def moe_apply_onehot(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style one-hot einsum dispatch (paper-faithful MoE baseline).

    O(T*E*C*D) dispatch FLOPs — kept as the reference implementation and the
    §Perf baseline; `moe_apply_scatter` is the optimized path.
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(s * k * cfg.capacity_factor / e))
    cap = min(cap, s * k)

    logits = (x @ cast(params["router"], dt)).astype(jnp.float32)  # (B,S,E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                            # (B,S,k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)    # renorm

    # Flatten the k slots: T = S*k successive (token, slot) pairs.
    t = s * k
    sel = topi.reshape(b, t)                                        # (B,T)
    w = topv.reshape(b, t)                                          # (B,T)
    onehot = jax.nn.one_hot(sel, e, dtype=jnp.float32)              # (B,T,E)
    pos = jnp.cumsum(onehot, axis=1) * onehot - 1.0                 # (B,T,E)
    keep = (pos >= 0) & (pos < cap)
    pos = jnp.clip(pos, 0, cap - 1).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = (onehot[..., None] * slot_oh).astype(dt)             # (B,T,E,C)

    x_slots = jnp.repeat(x, k, axis=1)                              # (B,T,D)
    h = jnp.einsum("btec,btd->becd", dispatch, x_slots)             # (B,E,C,D)
    h = _expert_ffn(params, h, dt)
    combine = dispatch * w[..., None, None].astype(dt)
    out = jnp.einsum("btec,becd->btd", combine, h)                  # (B,T,D)
    out = out.reshape(b, s, k, d).sum(axis=2)

    # Switch load-balancing loss: E * sum_e f_e * p_e.
    frac_tokens = jnp.mean(jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(gates, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def moe_apply_scatter(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter/gather capacity dispatch (§Perf hillclimb, MegaBlocks-adjacent).

    Replaces the O(T*E*C*D) one-hot dispatch/combine einsums with
    O(T*k*D) scatter-add into per-expert capacity buffers and a gather back:

      slot  = expert_id * C + position_in_expert     (cumsum over one-hot)
      buf   = zeros(B, E*C, D).at[b, slot].add(x)    (dropped slots -> sink)
      h     = expert_ffn(buf)                        (same batched GEMMs)
      out   = h[b, slot] * gate

    Expert GEMM FLOPs are capacity_factor x the useful compute; everything
    else is data movement.  Token-drop semantics identical to the one-hot
    path (same position-in-expert order), so outputs match exactly.
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(s * k * cfg.capacity_factor / e))
    cap = min(cap, s * k)

    logits = (x @ cast(params["router"], dt)).astype(jnp.float32)   # (B,S,E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                             # (B,S,k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    t = s * k
    sel = topi.reshape(b, t)                                         # (B,T)
    w = topv.reshape(b, t).astype(dt)
    onehot = jax.nn.one_hot(sel, e, dtype=jnp.float32)               # (B,T,E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1.0        # (B,T)
    keep = (pos >= 0) & (pos < cap)
    slot = jnp.where(keep, sel * cap + pos.astype(jnp.int32), e * cap)

    # Constrain every scatter/gather OPERAND to stay batch-sharded — if the
    # zeros or indices are left unannotated GSPMD replicates the scatter and
    # all-reduces the full (B, E*C, D) buffer (§Perf A2: 35 GB/layer).
    x_slots = _shard_batch(jnp.repeat(x, k, axis=1), cfg)            # (B,T,D)
    slot = _shard_batch(slot, cfg)
    bidx = jnp.arange(b)[:, None]
    zeros = _shard_batch(jnp.zeros((b, e * cap + 1, d), dt), cfg)
    buf = zeros.at[bidx, slot].add(x_slots * keep[..., None].astype(dt))
    buf = _shard_batch(buf, cfg)
    h = _expert_ffn(params, buf[:, : e * cap].reshape(b, e, cap, d), dt)
    h = _shard_batch(h, cfg)
    y = h.reshape(b, e * cap, d)
    y = jnp.concatenate([y, jnp.zeros((b, 1, d), dt)], axis=1)       # sink row
    out = _shard_batch(y[bidx, slot], cfg) * (w * keep.astype(dt))[..., None]
    out = _shard_batch(out.reshape(b, s, k, d).sum(axis=2), cfg)

    frac_tokens = jnp.mean(jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(gates, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def moe_apply_decode(params, x, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, 1, D). Dense mixture over top-k experts (see module docstring)."""
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = (x @ cast(params["router"], dt)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    mix = jnp.zeros_like(gates).at[
        jnp.arange(b)[:, None, None],
        jnp.arange(s)[None, :, None],
        topi,
    ].set(topv)                                                     # (B,S,E)
    g = jnp.einsum("bsd,edf->bsef", x, cast(params["w_gate"], dt))
    u = jnp.einsum("bsd,edf->bsef", x, cast(params["w_up"], dt))
    a = jax.nn.silu(g) * u
    o = jnp.einsum("bsef,efd->bsed", a, cast(params["w_down"], dt))
    return jnp.einsum("bse,bsed->bsd", mix.astype(dt), o)
