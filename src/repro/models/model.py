"""Model assembly: every assigned architecture behind one interface.

`LM(cfg)` exposes:

  init(rng)                               -> params (fp32 masters)
  loss(params, batch)                     -> scalar train loss
  prefill(params, batch, max_len)         -> (last logits, kv/ssm cache)
  decode_step(params, cache, token, pos)  -> (logits, cache)
  init_cache(batch_size, max_len)         -> zeroed cache pytree (dry-run)

Layer stacks are scanned (`jax.lax.scan` over stacked param pytrees) with
optional per-block remat — one HLO instance per block type regardless of
depth, which is what keeps the 512-way dry-run compile tractable.

batch dict keys:
  tokens (B,S) int32; labels (B,S) int32  (next-token targets)
  enc_frames (B,Tenc,D)  — whisper stub frontend output
  img_embeds (B,Timg,D)  — llama-vision stub frontend output
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import blocks as B
from repro.models.layers import (
    cast,
    embed_apply,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_positions,
    unembed_apply,
)

AUX_LOSS_WEIGHT = 0.01


def _stack_init(init_fn, key, n: int):
    """Initialize ``n`` layers and stack leaves on axis 0 (for lax.scan)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _group_tree(tree, groups: int, per: int):
    """Reshape stacked layer tree (G*P, ...) -> (G, P, ...)."""
    return jax.tree.map(lambda x: x.reshape((groups, per) + x.shape[1:]), tree)


def _shard_seq(x, cfg: ModelConfig):
    """Sequence-parallel constraint (Korthikanti et al.): pin the residual
    stream to (batch-axes, "model", None) between blocks so GSPMD turns the
    TP all-reduces into reduce-scatter + all-gather pairs (half the bytes;
    norms/pointwise work also shards over the model axis)."""
    if not (cfg.seq_shard_activations and cfg.act_shard_axes):
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, P(tuple(cfg.act_shard_axes), "model", None)
    )


def _scan_blocks(body, carry, xs, cfg: ModelConfig):
    """lax.scan over stacked layers, or an unrolled Python loop when
    ``cfg.scan_layers`` is False (used by the dry-run cost probes, where
    while-loop bodies would be counted once by HloCostAnalysis)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xs_i = jax.tree.map(lambda x: x[i], xs)
        carry, y = body(carry, xs_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    else:
        ys = None
    return carry, ys


@dataclasses.dataclass
class LM:
    cfg: ModelConfig

    # ------------------------------------------------------------- init ---
    def init(self, rng) -> Dict:
        cfg = self.cfg
        k_emb, k_layers, k_extra, k_enc = jax.random.split(rng, 4)
        params: Dict = {
            "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
            "ln_f": rmsnorm_init(cfg.d_model),
        }
        fam = cfg.family
        if fam == "dense":
            params["blocks"] = _stack_init(
                lambda k: B.attn_mlp_init(k, cfg), k_layers, cfg.n_layers
            )
        elif fam == "moe":
            params["blocks"] = _stack_init(
                lambda k: B.moe_block_init(k, cfg), k_layers, cfg.n_layers
            )
        elif fam == "ssm":
            params["blocks"] = _stack_init(
                lambda k: B.mamba_block_init(k, cfg), k_layers, cfg.n_layers
            )
        elif fam == "hybrid":
            groups = cfg.n_layers // cfg.shared_attn_period
            rem = cfg.n_layers - groups * cfg.shared_attn_period
            params["blocks"] = _stack_init(
                lambda k: B.mamba_block_init(k, cfg), k_layers,
                groups * cfg.shared_attn_period,
            )
            if rem:
                params["tail"] = _stack_init(
                    lambda k: B.mamba_block_init(k, cfg), k_enc, rem
                )
            params["shared_attn"] = B.attn_mlp_init(k_extra, cfg)
        elif fam == "encdec":
            params["encoder"] = _stack_init(
                lambda k: B.attn_mlp_init(k, cfg), k_enc, cfg.n_encoder_layers
            )
            params["ln_enc"] = rmsnorm_init(cfg.d_model)
            params["blocks"] = _stack_init(
                lambda k: self._encdec_block_init(k), k_layers, cfg.n_layers
            )
        elif fam == "vlm":
            groups = cfg.n_layers // cfg.cross_attn_period
            params["blocks"] = _stack_init(
                lambda k: B.attn_mlp_init(k, cfg), k_layers, cfg.n_layers
            )
            params["cross_blocks"] = _stack_init(
                lambda k: B.cross_block_init(k, cfg, with_mlp=False), k_extra, groups
            )
        else:
            raise ValueError(fam)
        return params

    def _encdec_block_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = B.attn_mlp_init(k1, cfg)
        p.update(
            {"ln_cross": rmsnorm_init(cfg.d_model), "cross": attn.cross_attn_init(k2, cfg)}
        )
        return p

    # ---------------------------------------------------------- helpers ---
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = embed_apply(params["embed"], tokens, cfg.dtype)
        if cfg.embed_scale:
            x = x * np.sqrt(cfg.d_model).astype(np.float32)
        if cfg.pos_embed == "sinusoidal":
            s = tokens.shape[-1]
            pos = sinusoidal_positions(jnp.arange(s), cfg.d_model)
            x = x + pos.astype(x.dtype)
        return x

    def _encode(self, params, enc_frames):
        """Whisper encoder over stubbed conv-frontend output (B,Tenc,D)."""
        cfg = self.cfg
        x = enc_frames.astype(jnp.dtype(cfg.dtype))
        pos = sinusoidal_positions(jnp.arange(x.shape[1]), cfg.d_model)
        x = x + pos.astype(x.dtype)

        def body(h, lp):
            return B.attn_mlp_apply(lp, h, cfg, causal=False), None

        body = _maybe_remat(body, cfg)
        x, _ = _scan_blocks(body, x, params["encoder"], cfg)
        return rmsnorm(params["ln_enc"], x)

    # ------------------------------------------------------------ train ---
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full teacher-forced forward -> (logits fp32 (B,S,V), aux loss)."""
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        aux = jnp.zeros((), jnp.float32)
        fam = cfg.family

        if fam == "dense":
            def body(h, lp):
                return _shard_seq(B.attn_mlp_apply(lp, h, cfg), cfg), None
            x, _ = _scan_blocks(_maybe_remat(body, cfg), x, params["blocks"], cfg)

        elif fam == "moe":
            def body(carry, lp):
                h, a = carry
                h, aux_l = B.moe_block_apply(lp, h, cfg)
                return (h, a + aux_l), None
            (x, aux), _ = _scan_blocks(
                _maybe_remat(body, cfg), (x, aux), params["blocks"], cfg)

        elif fam == "ssm":
            def body(h, lp):
                return B.mamba_block_apply(lp, h, cfg), None
            x, _ = _scan_blocks(_maybe_remat(body, cfg), x, params["blocks"], cfg)

        elif fam == "hybrid":
            per = cfg.shared_attn_period
            groups = cfg.n_layers // per
            grouped = _group_tree(params["blocks"], groups, per)
            shared = params["shared_attn"]

            def group_body(h, gp):
                def inner(hh, lp):
                    return B.mamba_block_apply(lp, hh, cfg), None
                h, _ = _scan_blocks(inner, h, gp, cfg)
                h = B.attn_mlp_apply(shared, h, cfg)
                return h, None

            x, _ = _scan_blocks(_maybe_remat(group_body, cfg), x, grouped, cfg)
            if "tail" in params:
                def tail_body(h, lp):
                    return B.mamba_block_apply(lp, h, cfg), None
                x, _ = _scan_blocks(tail_body, x, params["tail"], cfg)

        elif fam == "encdec":
            ctx = self._encode(params, batch["enc_frames"])

            def body(h, lp):
                h = h + attn.attend_full(
                    lp["attn"], rmsnorm(lp["ln_attn"], h), cfg, causal=True,
                    use_rope=False,
                )
                h = B.cross_block_apply(
                    {"ln_x": lp["ln_cross"], "cross": lp["cross"]}, h, ctx, cfg
                )
                from repro.models.layers import mlp_apply
                h = h + mlp_apply(lp["mlp"], rmsnorm(lp["ln_mlp"], h), cfg.mlp_type)
                return h, None

            x, _ = _scan_blocks(_maybe_remat(body, cfg), x, params["blocks"], cfg)

        elif fam == "vlm":
            per = cfg.cross_attn_period
            groups = cfg.n_layers // per
            grouped = _group_tree(params["blocks"], groups, per)
            ctx = batch["img_embeds"].astype(x.dtype)

            def group_body(h, xs):
                gp, cp = xs
                def inner(hh, lp):
                    return B.attn_mlp_apply(lp, hh, cfg), None
                h, _ = _scan_blocks(inner, h, gp, cfg)
                h = B.cross_block_apply(cp, h, ctx, cfg)
                return h, None

            x, _ = _scan_blocks(
                _maybe_remat(group_body, cfg), x, (grouped, params["cross_blocks"]), cfg)
        else:
            raise ValueError(fam)

        x = rmsnorm(params["ln_f"], x)
        logits = unembed_apply(params["embed"], x, cfg.logit_softcap)
        return logits, aux

    def loss(self, params, batch) -> jnp.ndarray:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return loss + AUX_LOSS_WEIGHT * aux

    # ---------------------------------------------------------- serving ---
    def init_cache(self, batch_size: int, max_len: int) -> Dict:
        cfg = self.cfg
        dt = cfg.dtype
        fam = cfg.family
        dh = cfg.head_dim
        kv_shape = (batch_size, max_len, cfg.n_kv_heads, dh)

        def kv_stack(n):
            return {
                "k": jnp.zeros((n,) + kv_shape, jnp.dtype(dt)),
                "v": jnp.zeros((n,) + kv_shape, jnp.dtype(dt)),
            }

        if fam in ("dense", "moe"):
            return kv_stack(cfg.n_layers)
        if fam == "ssm":
            from repro.models.ssm import init_ssm_state
            st = init_ssm_state(cfg, batch_size, dt)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), st
            )
        if fam == "hybrid":
            from repro.models.ssm import init_ssm_state
            per = cfg.shared_attn_period
            groups = cfg.n_layers // per
            rem = cfg.n_layers - groups * per
            st = init_ssm_state(cfg, batch_size, dt)
            cache = {
                "mamba": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (groups * per,) + x.shape), st
                ),
                "shared": kv_stack(groups),
            }
            if rem:
                cache["tail"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (rem,) + x.shape), st
                )
            return cache
        if fam == "encdec":
            c = kv_stack(cfg.n_layers)
            tenc = cfg.encoder_seq
            c["cross_k"] = jnp.zeros(
                (cfg.n_layers, batch_size, tenc, cfg.n_kv_heads, dh), jnp.dtype(dt)
            )
            c["cross_v"] = jnp.zeros_like(c["cross_k"])
            return c
        if fam == "vlm":
            groups = cfg.n_layers // cfg.cross_attn_period
            c = kv_stack(cfg.n_layers)
            c["cross_k"] = jnp.zeros(
                (groups, batch_size, cfg.n_image_tokens, cfg.n_kv_heads, dh),
                jnp.dtype(dt),
            )
            c["cross_v"] = jnp.zeros_like(c["cross_k"])
            return c
        raise ValueError(fam)

    def prefill(self, params, batch, max_len: int) -> Tuple[jnp.ndarray, Dict]:
        """Teacher-forced pass that also fills the serving cache."""
        cfg = self.cfg
        fam = cfg.family
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        x = self._embed(params, tokens)
        cache = self.init_cache(bsz, max_len)

        def pad_kv(kv):
            k, v = kv
            pad = max_len - s
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return k.astype(jnp.dtype(cfg.dtype)), v.astype(jnp.dtype(cfg.dtype))

        if fam in ("dense", "moe"):
            def body(h, lp):
                if fam == "dense":
                    h, kv = B.attn_mlp_apply(lp, h, cfg, return_kv=True)
                else:
                    h, _aux, kv = B.moe_block_apply(lp, h, cfg, return_kv=True)
                return h, pad_kv(kv)
            x, (ks, vs) = _scan_blocks(_maybe_remat(body, cfg), x, params["blocks"], cfg)
            cache = {"k": ks, "v": vs}

        elif fam == "ssm":
            # Run the train path for logits; rebuild final states by replaying
            # the recurrence on the last conv_width tokens is equivalent only
            # for conv; the SSM state needs the full scan — use decode-free
            # prefill: chunked apply returns states via a second pass.
            x, cache = self._ssm_prefill(params, x, cache)

        elif fam == "hybrid":
            x, cache = self._hybrid_prefill(params, x, cache)

        elif fam == "encdec":
            ctx = self._encode(params, batch["enc_frames"])

            # explicit loop body (self + cross + mlp), collecting both caches
            def body2(h, lp):
                hself, kv = attn.attend_full(
                    lp["attn"], rmsnorm(lp["ln_attn"], h), cfg, causal=True,
                    use_rope=False, return_kv=True,
                )
                h = h + hself
                h = B.cross_block_apply(
                    {"ln_x": lp["ln_cross"], "cross": lp["cross"]}, h, ctx, cfg
                )
                from repro.models.layers import mlp_apply
                h = h + mlp_apply(lp["mlp"], rmsnorm(lp["ln_mlp"], h), cfg.mlp_type)
                ck, cv = B.cross_context_kv(lp, ctx, cfg)
                return h, (pad_kv(kv), (ck, cv))
            x, (kvs, cross) = _scan_blocks(_maybe_remat(body2, cfg), x, params["blocks"], cfg)
            cache = {
                "k": kvs[0], "v": kvs[1],
                "cross_k": cross[0], "cross_v": cross[1],
            }

        elif fam == "vlm":
            per = cfg.cross_attn_period
            groups = cfg.n_layers // per
            grouped = _group_tree(params["blocks"], groups, per)
            ctx = batch["img_embeds"].astype(x.dtype)

            def group_body(h, xs):
                gp, cp = xs
                def inner(hh, lp):
                    hh, kv = B.attn_mlp_apply(lp, hh, cfg, return_kv=True)
                    return hh, pad_kv(kv)
                h, kvs = _scan_blocks(inner, h, gp, cfg)
                h = B.cross_block_apply(cp, h, ctx, cfg)
                ck, cv = B.cross_context_kv(cp, ctx, cfg)
                return h, (kvs, (ck, cv))
            x, (kvs, cross) = _scan_blocks(
                _maybe_remat(group_body, cfg), x, (grouped, params["cross_blocks"]), cfg)
            ks = kvs[0].reshape((cfg.n_layers,) + kvs[0].shape[2:])
            vs = kvs[1].reshape((cfg.n_layers,) + kvs[1].shape[2:])
            cache = {"k": ks, "v": vs, "cross_k": cross[0], "cross_v": cross[1]}
        else:
            raise ValueError(fam)

        x = rmsnorm(params["ln_f"], x)
        logits = unembed_apply(params["embed"], x[:, -1:], cfg.logit_softcap)
        return logits[:, 0], cache

    def _ssm_prefill(self, params, x, cache):
        cfg = self.cfg
        del cache  # rebuilt from scratch below

        def body(h, lp):
            h, st = B.mamba_block_apply(lp, h, cfg, return_state=True)
            return h, (st["conv"].astype(jnp.dtype(cfg.dtype)), st["ssm"])

        x, (convs, ssms) = _scan_blocks(_maybe_remat(body, cfg), x, params["blocks"], cfg)
        return x, {"conv": convs, "ssm": ssms}

    def _hybrid_prefill(self, params, x, cache):
        cfg = self.cfg
        per = cfg.shared_attn_period
        groups = cfg.n_layers // per
        grouped = _group_tree(params["blocks"], groups, per)
        shared = params["shared_attn"]
        max_len = cache["shared"]["k"].shape[2]
        s = x.shape[1]

        def group_body(h, gp):
            def inner(hh, lp):
                hh, st = B.mamba_block_apply(lp, hh, cfg, return_state=True)
                return hh, (st["conv"].astype(jnp.dtype(cfg.dtype)), st["ssm"])

            h, (convs, ssms) = _scan_blocks(inner, h, gp, cfg)
            h, kv = B.attn_mlp_apply(shared, h, cfg, return_kv=True)
            k, v = kv
            k = jnp.pad(k, ((0, 0), (0, max_len - s), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, max_len - s), (0, 0), (0, 0)))
            return h, ((convs, ssms), (k.astype(jnp.dtype(cfg.dtype)), v.astype(jnp.dtype(cfg.dtype))))

        x, (mstates, kvs) = _scan_blocks(_maybe_remat(group_body, cfg), x, grouped, cfg)
        new_cache = {
            "mamba": {
                "conv": mstates[0].reshape((groups * per,) + mstates[0].shape[2:]),
                "ssm": mstates[1].reshape((groups * per,) + mstates[1].shape[2:]),
            },
            "shared": {"k": kvs[0], "v": kvs[1]},
        }
        if "tail" in params:
            def tail_body(h, lp):
                h, st = B.mamba_block_apply(lp, h, cfg, return_state=True)
                return h, (st["conv"].astype(jnp.dtype(cfg.dtype)), st["ssm"])
            x, (tc, ts) = _scan_blocks(tail_body, x, params["tail"], cfg)
            new_cache["tail"] = {"conv": tc, "ssm": ts}
        return x, new_cache

    # ------------------------------------------------------ decode step ---
    def decode_step(self, params, cache, token, pos):
        """token: (B, 1) int32; pos: scalar int32. Returns (logits (B,V), cache)."""
        cfg = self.cfg
        fam = cfg.family
        x = self._embed_decode(params, token, pos)

        if fam in ("dense", "moe"):
            def body(h, xs):
                lp, ck, cv = xs
                if fam == "dense":
                    h, c = B.attn_mlp_decode(lp, h, {"k": ck, "v": cv}, pos, cfg)
                else:
                    h, c = B.moe_block_decode(lp, h, {"k": ck, "v": cv}, pos, cfg)
                return h, (c["k"], c["v"])
            x, (ks, vs) = _scan_blocks(body, x, (params["blocks"], cache["k"], cache["v"]), cfg)
            cache = {"k": ks, "v": vs}

        elif fam == "ssm":
            def body(h, xs):
                lp, cst, sst = xs
                h, st = B.mamba_block_decode(lp, h, {"conv": cst, "ssm": sst}, cfg)
                return h, (st["conv"], st["ssm"])
            x, (cs, ss) = _scan_blocks(body, x, (params["blocks"], cache["conv"], cache["ssm"]), cfg)
            cache = {"conv": cs, "ssm": ss}

        elif fam == "hybrid":
            x, cache = self._hybrid_decode(params, cache, x, pos)

        elif fam == "encdec":
            def body(h, xs):
                lp, ck, cv, xk, xv = xs
                h2, c = attn.attend_decode(
                    lp["attn"], rmsnorm(lp["ln_attn"], h), {"k": ck, "v": cv}, pos, cfg
                )
                h = h + h2
                h = B.cross_block_decode_cached(
                    {"ln_x": lp["ln_cross"], "cross": lp["cross"]}, h, xk, xv, cfg
                )
                from repro.models.layers import mlp_apply
                h = h + mlp_apply(lp["mlp"], rmsnorm(lp["ln_mlp"], h), cfg.mlp_type)
                return h, (c["k"], c["v"])
            x, (ks, vs) = _scan_blocks(
                body, x,
                (params["blocks"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]), cfg)
            cache = dict(cache, k=ks, v=vs)

        elif fam == "vlm":
            per = cfg.cross_attn_period
            groups = cfg.n_layers // per
            grouped = _group_tree(params["blocks"], groups, per)
            gk = cache["k"].reshape((groups, per) + cache["k"].shape[1:])
            gv = cache["v"].reshape((groups, per) + cache["v"].shape[1:])

            def group_body(h, xs):
                gp, cp, ck, cv, xk, xv = xs
                def inner(hh, xs2):
                    lp, k1, v1 = xs2
                    hh, c = B.attn_mlp_decode(lp, hh, {"k": k1, "v": v1}, pos, cfg)
                    return hh, (c["k"], c["v"])
                h, (ks, vs) = _scan_blocks(inner, h, (gp, ck, cv), cfg)
                h = B.cross_block_decode_cached(cp, h, xk, xv, cfg)
                return h, (ks, vs)

            x, (ks, vs) = _scan_blocks(
                group_body, x,
                (grouped, params["cross_blocks"], gk, gv, cache["cross_k"], cache["cross_v"]), cfg)
            cache = dict(
                cache,
                k=ks.reshape(cache["k"].shape),
                v=vs.reshape(cache["v"].shape),
            )
        else:
            raise ValueError(fam)

        x = rmsnorm(params["ln_f"], x)
        logits = unembed_apply(params["embed"], x, cfg.logit_softcap)
        return logits[:, 0], cache

    def _embed_decode(self, params, token, pos):
        cfg = self.cfg
        x = embed_apply(params["embed"], token, cfg.dtype)
        if cfg.embed_scale:
            x = x * np.sqrt(cfg.d_model).astype(np.float32)
        if cfg.pos_embed == "sinusoidal":
            p = sinusoidal_positions(jnp.full((1,), pos), cfg.d_model)
            x = x + p.astype(x.dtype)
        return x

    def _hybrid_decode(self, params, cache, x, pos):
        cfg = self.cfg
        per = cfg.shared_attn_period
        groups = cfg.n_layers // per
        grouped = _group_tree(params["blocks"], groups, per)
        g_conv = cache["mamba"]["conv"].reshape((groups, per) + cache["mamba"]["conv"].shape[1:])
        g_ssm = cache["mamba"]["ssm"].reshape((groups, per) + cache["mamba"]["ssm"].shape[1:])
        shared = params["shared_attn"]

        def group_body(h, xs):
            gp, cst, sst, sk, sv = xs
            def inner(hh, xs2):
                lp, c1, s1 = xs2
                hh, st = B.mamba_block_decode(lp, hh, {"conv": c1, "ssm": s1}, cfg)
                return hh, (st["conv"], st["ssm"])
            h, (cs, ss) = _scan_blocks(inner, h, (gp, cst, sst), cfg)
            h, c = B.attn_mlp_decode(shared, h, {"k": sk, "v": sv}, pos, cfg)
            return h, ((cs, ss), (c["k"], c["v"]))

        x, (mst, kvs) = _scan_blocks(
            group_body, x,
            (grouped, g_conv, g_ssm, cache["shared"]["k"], cache["shared"]["v"]), cfg)
        new_cache = {
            "mamba": {
                "conv": mst[0].reshape(cache["mamba"]["conv"].shape),
                "ssm": mst[1].reshape(cache["mamba"]["ssm"].shape),
            },
            "shared": {"k": kvs[0], "v": kvs[1]},
        }
        if "tail" in params:
            def tail_body(h, xs):
                lp, c1, s1 = xs
                h, st = B.mamba_block_decode(lp, h, {"conv": c1, "ssm": s1}, cfg)
                return h, (st["conv"], st["ssm"])
            x, (tc, ts) = _scan_blocks(
                tail_body, x, (params["tail"], cache["tail"]["conv"], cache["tail"]["ssm"]), cfg)
            new_cache["tail"] = {"conv": tc, "ssm": ts}
        return x, new_cache


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
