"""Elementary layers: norms, RoPE/sinusoidal positions, MLP variants, embeds.

Pure functions over param dicts.  Compute dtype is cfg.dtype (bf16 on TPU);
master params stay fp32 and are cast at use ("cast-on-use" mixed precision).
Initializers follow common practice (trunc-normal 0.02 / scaled by fan-in).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def cast(x, dtype: str):
    return x.astype(jnp.dtype(dtype))


# ----------------------------------------------------------------- norms ---
def rmsnorm_init(d: int) -> Dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


# ------------------------------------------------------------- positions ---
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S). Rotates pairs (even, odd)."""
    d = x.shape[-1]
    inv = jnp.asarray(rope_frequencies(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d_model: int):
    """Classic transformer sinusoids. positions: (..., S) -> (..., S, D)."""
    half = d_model // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------------------------------------------- mlp ---
def mlp_init(key, d_model: int, d_ff: int, mlp_type: str) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    p = {
        "w_up": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(k2, (d_ff, d_model), jnp.float32) * s_out,
    }
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), jnp.float32) * s_in
    return p


def mlp_apply(params, x, mlp_type: str):
    dt = x.dtype
    up = x @ cast(params["w_up"], dt)
    if mlp_type == "swiglu":
        g = x @ cast(params["w_gate"], dt)
        h = jax.nn.silu(g) * up
    elif mlp_type == "geglu":
        g = x @ cast(params["w_gate"], dt)
        h = jax.nn.gelu(g, approximate=True) * up
    elif mlp_type == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(mlp_type)
    return h @ cast(params["w_down"], dt)


# ------------------------------------------------------------ embeddings ---
def embed_init(key, vocab: int, d_model: int, tie: bool) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {"embedding": jax.random.normal(k1, (vocab, d_model), jnp.float32) * 0.02}
    if not tie:
        p["unembed"] = (
            jax.random.normal(k2, (vocab, d_model), jnp.float32) / np.sqrt(d_model)
        )
    return p


def embed_apply(params, tokens, dtype: str):
    return cast(params["embedding"], dtype)[tokens]


def unembed_apply(params, x, softcap: Optional[float] = None):
    table = params.get("unembed", params["embedding"])
    logits = (x @ cast(table, x.dtype).T).astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
