"""Jitted public wrappers for the warp kernels.

``interpret`` defaults to True because this container is CPU-only; on a real
TPU deployment set ``repro.kernels.INTERPRET = False`` (or pass explicitly)
and the same BlockSpecs lower through Mosaic.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.warp.warp import autotune_block_rows  # noqa: F401 (re-export)
from repro.kernels.warp.warp import coadd_clip as _coadd_clip
from repro.kernels.warp.warp import coadd_fused as _coadd_fused
from repro.kernels.warp.warp import coadd_hist as _coadd_hist
from repro.kernels.warp.warp import coadd_moments as _coadd_moments
from repro.kernels.warp.warp import mosaic_bricks as _mosaic_bricks
from repro.kernels.warp.warp import warp_project as _warp_project


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def warp_project(image, wcs_vec, accept, grid_ra, grid_dec, block_rows=8, interpret=True):
    return _warp_project(
        image, wcs_vec, accept, grid_ra, grid_dec,
        block_rows=block_rows, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def warp_batch(pixels, wcs_vecs, accepts, grid_ra, grid_dec, block_rows=8, interpret=True):
    """(N,H,W) -> (N,Q,Q) tiles + coverages, vmapping the single-image kernel."""
    fn = lambda p, w, a: _warp_project(  # noqa: E731
        p, w, a, grid_ra, grid_dec, block_rows=block_rows, interpret=interpret
    )
    return jax.vmap(fn)(pixels, wcs_vecs, accepts)


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def coadd_fused(pixels, wcs_vecs, accepts, grid_ra, grid_dec, psf_kernels=None,
                block_rows=8, interpret=True):
    """Fused map+reduce: (N,H,W) images -> (Q,Q) coadd + depth.

    ``psf_kernels`` (N, K), when given, PSF-matches each image inside the
    kernel before warping (banded-matmul separable convolution).
    """
    return _coadd_fused(
        pixels, wcs_vecs, accepts, grid_ra, grid_dec, psf_kernels=psf_kernels,
        block_rows=block_rows, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def coadd_moments(pixels, wcs_vecs, accepts, grid_ra, grid_dec,
                  psf_kernels=None, block_rows=8, interpret=True):
    """Fused robust pass 1: (N,H,W) images -> (S0, S1, S2) moment maps."""
    return _coadd_moments(
        pixels, wcs_vecs, accepts, grid_ra, grid_dec, psf_kernels=psf_kernels,
        block_rows=block_rows, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def coadd_clip(pixels, wcs_vecs, accepts, grid_ra, grid_dec, center, thresh,
               psf_kernels=None, block_rows=8, interpret=True):
    """Fused robust final pass: accumulate samples inside the clip window."""
    return _coadd_clip(
        pixels, wcs_vecs, accepts, grid_ra, grid_dec, center, thresh,
        psf_kernels=psf_kernels, block_rows=block_rows, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("nbins", "block_rows", "interpret"))
def coadd_hist(pixels, wcs_vecs, accepts, grid_ra, grid_dec, lo, inv_w,
               nbins=16, psf_kernels=None, block_rows=8, interpret=True):
    """Fused median round 1: (nbins, Q, Q) weighted binapprox histogram."""
    return _coadd_hist(
        pixels, wcs_vecs, accepts, grid_ra, grid_dec, lo, inv_w, nbins=nbins,
        psf_kernels=psf_kernels, block_rows=block_rows, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("npix", "interpret"))
def mosaic_bricks(tiles, covs, offsets, npix, interpret=True):
    """(B,bh,bw) cached brick tiles + weights -> (npix,npix) coadd + depth."""
    return _mosaic_bricks(tiles, covs, offsets, npix, interpret=interpret)
