"""Pure-jnp oracle for the warp kernel: exactly the mapper's projection."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mapper import project_one


def warp_project_ref(image, wcs_vec, accept, grid_ra, grid_dec):
    """(H,W) image -> (Q,Q) projected tile + coverage. Oracle."""
    return project_one(image, wcs_vec, accept, grid_ra, grid_dec)


def warp_batch_ref(pixels, wcs_vecs, accepts, grid_ra, grid_dec):
    return jax.vmap(warp_project_ref, in_axes=(0, 0, 0, None, None))(
        pixels, wcs_vecs, accepts, grid_ra, grid_dec
    )


def coadd_fused_ref(pixels, wcs_vecs, accepts, grid_ra, grid_dec):
    """Map + reduce oracle: sum of projected tiles and coverages."""
    tiles, covs = warp_batch_ref(pixels, wcs_vecs, accepts, grid_ra, grid_dec)
    return tiles.sum(0), covs.sum(0)
