"""Pallas TPU kernel: inverse-warp projection (the paper's mapper hot spot).

Hardware adaptation (DESIGN.md §2): the mapper's projection is a bilinear
*gather* — the classic GPU formulation (one thread per output pixel doing
random-access texture reads) has no TPU analogue, since the VPU wants dense
vectors and the MXU wants matmuls.  We therefore reformulate the gather as
structured dense algebra:

  1. For an output row-block, compute source coordinates (sx, sy) on the VPU
     (gnomonic trig is elementwise).
  2. **Row gather as matmul**: rows0 = onehot(y0) @ image puts the two
     needed source rows of every output pixel into registers via the MXU —
     gathers become 8x128-aligned matmuls.
  3. **Column select as masked reduction**: v = sum(rows * onehot(x), axis=1)
     on the VPU.
  4. Bilinear combine + acceptance gating (the Algorithm-2 filter is one
     multiply — "discarding false positives is cheap", paper §4.1.4).

Two kernels:

* ``warp_project``  — one image -> one projected tile (+coverage).
* ``coadd_fused``   — Algorithm 1 in a single kernel: grid (row_block, image)
  iterates images innermost and accumulates the coadd/depth in the output
  block across grid steps (matmul-k-loop idiom), so the (N, Q, Q) stack of
  projected tiles never materializes in HBM.  This is the map+reduce fusion
  the MapReduce framing forbids Hadoop but the TPU gives us for free.

VMEM budget per grid step: image (H*W*4) + 2 onehot row blocks
(block_rows*Q*max(H,W)*4) + tile blocks; block_rows is the tuning knob.
All matmul dims should be multiples of (8, 128) for MXU efficiency — tests
sweep misaligned shapes through the interpret-mode path for correctness.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEG2RAD = float(jnp.pi / 180.0)
RAD2DEG = float(180.0 / jnp.pi)


def _tpu_params(dimension_semantics):
    """Mosaic compiler params (annotates grid-dim parallelism on real TPU)."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except Exception:  # pragma: no cover - older/newer API drift
        return None


def autotune_block_rows(
    q: int,
    h: int,
    w: int,
    vmem_budget_bytes: int = 4 << 20,
    candidates=(128, 64, 48, 32, 24, 16, 12, 8, 6, 4, 3, 2, 1),
    psf_kernel_width: int = 0,
    psf_kernel_2d: bool = False,
) -> int:
    """Largest ``block_rows`` dividing ``q`` whose grid step fits the budget.

    Per-step VMEM for ``coadd_fused`` (DESIGN.md §2): the source image, two
    onehot row-gather operands of shape (block_rows*q, h), two gathered row
    blocks + two onehot column masks of shape (block_rows*q, w), and four
    (block_rows, q) grid/output blocks — all float32.  When the PSF-matching
    variant runs (``psf_kernel_width`` > 0), each step additionally holds the
    (h, h) and (w, w) band matrices, the convolved image copy, and the
    kernel row — a block_rows-independent term, but it still shrinks the
    space left for the row blocks.  The measured-PSF 2-D variant
    (``psf_kernel_2d``) rebuilds a band pair per kernel row; only one pair is
    live at a time, but its (Kh, Kw) tap block and the accumulating output
    copy join the image, so the constant term grows by ~h*w + K^2.
    The default budget leaves ample headroom in ~16 MB of VMEM for double
    buffering.
    """
    if psf_kernel_width > 1 and psf_kernel_2d:
        psf_bytes = 4 * (
            h * h + w * w + 2 * h * w + psf_kernel_width * psf_kernel_width
        )
    elif psf_kernel_width > 1:
        psf_bytes = 4 * (h * h + w * w + h * w + psf_kernel_width)
    else:
        psf_bytes = 0
    for b in candidates:
        if b > q or q % b:
            continue
        n = b * q
        step_bytes = 4 * (h * w + 2 * n * h + 4 * n * w + 4 * n) + psf_bytes
        if step_bytes <= vmem_budget_bytes:
            return b
    return 1


def _sky_to_pixel(gra, gdec, w):
    """Gnomonic sky->pixel for a block. ``w`` is the 8-vector (see geometry)."""
    ra0, dec0 = w[0], w[1]
    x0, y0 = w[2], w[3]
    cd11, cd12, cd21, cd22 = w[4], w[5], w[6], w[7]
    ra_r = gra * DEG2RAD
    dec_r = gdec * DEG2RAD
    ra0_r = ra0 * DEG2RAD
    dec0_r = dec0 * DEG2RAD
    sin_dec = jnp.sin(dec_r)
    cos_dec = jnp.cos(dec_r)
    sin_dec0 = jnp.sin(dec0_r)
    cos_dec0 = jnp.cos(dec0_r)
    dra = ra_r - ra0_r
    cosc = sin_dec0 * sin_dec + cos_dec0 * cos_dec * jnp.cos(dra)
    xi = cos_dec * jnp.sin(dra) / cosc * RAD2DEG
    eta = (cos_dec0 * sin_dec - sin_dec0 * cos_dec * jnp.cos(dra)) / cosc * RAD2DEG
    det = cd11 * cd22 - cd12 * cd21
    sx = (cd22 * xi - cd12 * eta) / det + x0
    sy = (-cd21 * xi + cd11 * eta) / det + y0
    return sx, sy


def _bilinear_via_matmul(image, sx, sy):
    """Bilinear sample as onehot-matmul row gather + masked column select."""
    h, w = image.shape
    bq, q = sx.shape
    n = bq * q
    sxf = sx.reshape(n)
    syf = sy.reshape(n)
    x0f = jnp.floor(sxf)
    y0f = jnp.floor(syf)
    dx = sxf - x0f
    dy = syf - y0f
    x0 = jnp.clip(x0f.astype(jnp.int32), 0, w - 1)
    x1 = jnp.clip(x0f.astype(jnp.int32) + 1, 0, w - 1)
    y0 = jnp.clip(y0f.astype(jnp.int32), 0, h - 1)
    y1 = jnp.clip(y0f.astype(jnp.int32) + 1, 0, h - 1)

    rows_iota = jax.lax.broadcasted_iota(jnp.int32, (n, h), 1)
    oh_y0 = (rows_iota == y0[:, None]).astype(image.dtype)
    oh_y1 = (rows_iota == y1[:, None]).astype(image.dtype)
    # MXU: (n, h) @ (h, w) row gathers.
    rows0 = jnp.dot(oh_y0, image, preferred_element_type=jnp.float32)
    rows1 = jnp.dot(oh_y1, image, preferred_element_type=jnp.float32)

    cols_iota = jax.lax.broadcasted_iota(jnp.int32, (n, w), 1)
    oh_x0 = (cols_iota == x0[:, None]).astype(image.dtype)
    oh_x1 = (cols_iota == x1[:, None]).astype(image.dtype)
    v00 = jnp.sum(rows0 * oh_x0, axis=1)
    v01 = jnp.sum(rows0 * oh_x1, axis=1)
    v10 = jnp.sum(rows1 * oh_x0, axis=1)
    v11 = jnp.sum(rows1 * oh_x1, axis=1)

    val = (
        v00 * (1 - dx) * (1 - dy)
        + v01 * dx * (1 - dy)
        + v10 * (1 - dx) * dy
        + v11 * dx * dy
    )
    inside = (sxf >= 0) & (sxf <= w - 1) & (syf >= 0) & (syf <= h - 1)
    m = inside.astype(image.dtype)
    return (val * m).reshape(bq, q), m.reshape(bq, q)


def _conv_band_matrix(kernel, n: int, dtype):
    """(n, n) banded matrix M with M @ x == edge-padded 1-D conv of x.

    M[i, j] = sum_m kernel[m] * [j == clip(i + m - r, 0, n-1)] — identical to
    ``jnp.convolve(pad(x, edge), kernel, 'valid')`` for the symmetric
    (Gaussian) kernels `matching_kernel_bank` emits.  Built from iotas and a
    static loop over the K taps, so the separable PSF convolution becomes two
    matmuls — the same dense-algebra reformulation as the row-gather (§2).
    """
    k_width = kernel.shape[0]
    r = (k_width - 1) // 2
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    m_mat = jnp.zeros((n, n), dtype)
    for m in range(k_width):
        src = jnp.clip(rows + (m - r), 0, n - 1)
        m_mat = m_mat + kernel[m] * (cols == src).astype(dtype)
    return m_mat


def _convolve_sep_matmul(image, kernel):
    """Separable PSF convolution as two MXU matmuls (edge-padded)."""
    if kernel.shape[0] == 1:
        return image * kernel[0]
    h, w = image.shape
    m_h = _conv_band_matrix(kernel, h, image.dtype)
    m_w = _conv_band_matrix(kernel, w, image.dtype)
    out = jnp.dot(image, m_w.T, preferred_element_type=jnp.float32)   # rows
    return jnp.dot(m_h, out, preferred_element_type=jnp.float32)      # cols


def _convolve_2d_matmul(image, kern2d):
    """Non-separable 2-D PSF correlation as Kh banded-matmul pairs.

    The measured-PSF homogenization kernels (`psf.homogenization_bank`) are
    full (Kh, Kw) tap grids — no separable factorization exists for an
    elliptical Moffat matching kernel.  Decompose by kernel *row* instead:

      out = sum_m  S_m @ (image @ W_m.T)

    where W_m is the banded matrix applying kernel row m along the width
    axis (`_conv_band_matrix` — a correlation with edge clamp) and S_m is
    the one-band row-shift selection ``S_m[i, j] = [j == clip(i+m-rh)]``.
    Both factors are iota-built dense matrices, so the whole convolution is
    2*Kh MXU matmuls — the same gather-as-matmul reformulation as the
    bilinear row gather (§2), which is what lets the PSF-matched image stay
    in registers instead of round-tripping through HBM.  Semantics match
    `psf.convolve_2d` exactly: edge-clamped cross-correlation.
    """
    kh, kw = kern2d.shape
    if kh == 1 and kw == 1:
        return image * kern2d[0, 0]
    h, w = image.shape
    rh = (kh - 1) // 2
    rows = jax.lax.broadcasted_iota(jnp.int32, (h, h), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (h, h), 1)
    out = jnp.zeros_like(image)
    for m in range(kh):
        w_m = _conv_band_matrix(kern2d[m], w, image.dtype)
        row_sel = (cols == jnp.clip(rows + (m - rh), 0, h - 1)).astype(
            image.dtype
        )
        shifted = jnp.dot(image, w_m.T, preferred_element_type=jnp.float32)
        out = out + jnp.dot(row_sel, shifted, preferred_element_type=jnp.float32)
    return out


def _warp_kernel(wcs_ref, accept_ref, image_ref, gra_ref, gdec_ref, tile_ref, cov_ref):
    w = wcs_ref[0, :]
    a = accept_ref[0, 0]
    sx, sy = _sky_to_pixel(gra_ref[...], gdec_ref[...], w)
    val, cov = _bilinear_via_matmul(image_ref[...], sx, sy)
    tile_ref[...] = val * a
    cov_ref[...] = cov * a


def warp_project(
    image: jnp.ndarray,     # (H, W)
    wcs_vec: jnp.ndarray,   # (8,)
    accept: jnp.ndarray,    # scalar
    grid_ra: jnp.ndarray,   # (Q, Q)
    grid_dec: jnp.ndarray,  # (Q, Q)
    *,
    block_rows: int = 8,
    interpret: bool = True,
):
    q = grid_ra.shape[0]
    h, w = image.shape
    block_rows = min(block_rows, q)
    if q % block_rows:
        raise ValueError(f"npix {q} must divide block_rows {block_rows}")
    wcs2 = wcs_vec.reshape(1, 8).astype(jnp.float32)
    acc2 = jnp.asarray(accept, jnp.float32).reshape(1, 1)
    grid = (q // block_rows,)
    out = pl.pallas_call(
        _warp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda r: (0, 0)),
            pl.BlockSpec((1, 1), lambda r: (0, 0)),
            pl.BlockSpec((h, w), lambda r: (0, 0)),
            pl.BlockSpec((block_rows, q), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, q), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, q), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, q), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, q), jnp.float32),
            jax.ShapeDtypeStruct((q, q), jnp.float32),
        ],
        interpret=interpret,
    )(wcs2, acc2, image.astype(jnp.float32), grid_ra, grid_dec)
    return out[0], out[1]


def _coadd_fused_kernel(
    wcs_ref, accept_ref, image_ref, gra_ref, gdec_ref, coadd_ref, depth_ref
):
    i = pl.program_id(1)  # image index — innermost: consecutive revisits
    w = wcs_ref[0, :]
    a = accept_ref[0, 0]
    sx, sy = _sky_to_pixel(gra_ref[...], gdec_ref[...], w)
    val, cov = _bilinear_via_matmul(image_ref[0], sx, sy)

    @pl.when(i == 0)
    def _init():
        coadd_ref[...] = val * a
        depth_ref[...] = cov * a

    @pl.when(i > 0)
    def _accum():
        coadd_ref[...] += val * a
        depth_ref[...] += cov * a


def _coadd_fused_psf_kernel(
    wcs_ref, accept_ref, kern_ref, image_ref, gra_ref, gdec_ref, coadd_ref, depth_ref
):
    """`_coadd_fused_kernel` + in-kernel PSF matching before the warp.

    The per-slot matching kernel row arrives as an operand; the separable
    convolution is two banded matmuls (`_convolve_sep_matmul`), so the
    PSF-matched image never round-trips through HBM either.

    Tradeoff: the convolution depends only on the image index but runs once
    per (row_block, image) grid step — a q/block_rows-fold recompute.  It
    cannot be hoisted without breaking the accumulate-innermost idiom (a
    scratch per image would be clobbered before the next row block returns
    to it; making images the outer grid dim would revisit output blocks
    non-consecutively, which the accumulation pattern forbids).  The band
    matmuls are MXU work of the same order as the row gather, so fusion
    still wins over materializing N convolved images in HBM.
    """
    i = pl.program_id(1)
    w = wcs_ref[0, :]
    a = accept_ref[0, 0]
    img = _convolve_sep_matmul(image_ref[0], kern_ref[0, :])
    sx, sy = _sky_to_pixel(gra_ref[...], gdec_ref[...], w)
    val, cov = _bilinear_via_matmul(img, sx, sy)

    @pl.when(i == 0)
    def _init():
        coadd_ref[...] = val * a
        depth_ref[...] = cov * a

    @pl.when(i > 0)
    def _accum():
        coadd_ref[...] += val * a
        depth_ref[...] += cov * a


def _coadd_fused_psf2d_kernel(
    wcs_ref, accept_ref, kern_ref, image_ref, gra_ref, gdec_ref, coadd_ref, depth_ref
):
    """`_coadd_fused_kernel` + in-kernel *measured-PSF* homogenization.

    The per-slot operand is a full (Kh, Kw) tap grid from
    `psf.homogenization_bank` (non-separable — elliptical Moffat matching
    kernels don't factor), applied as Kh banded-matmul pairs
    (`_convolve_2d_matmul`) before the warp, so the homogenized image never
    round-trips through HBM.  Same recompute tradeoff as the separable
    variant: the convolution depends only on the image index but runs once
    per (row_block, image) grid step — q/block_rows-fold recompute that
    cannot be hoisted without breaking the accumulate-innermost idiom.  The
    engine's matched-pixel cache (DESIGN.md §7) is the other end of that
    tradeoff: convolve once at residency time, spend HBM instead of MXU.
    """
    i = pl.program_id(1)
    w = wcs_ref[0, :]
    a = accept_ref[0, 0]
    img = _convolve_2d_matmul(image_ref[0], kern_ref[0])
    sx, sy = _sky_to_pixel(gra_ref[...], gdec_ref[...], w)
    val, cov = _bilinear_via_matmul(img, sx, sy)

    @pl.when(i == 0)
    def _init():
        coadd_ref[...] = val * a
        depth_ref[...] = cov * a

    @pl.when(i > 0)
    def _accum():
        coadd_ref[...] += val * a
        depth_ref[...] += cov * a


def coadd_fused(
    pixels: jnp.ndarray,    # (N, H, W)
    wcs_vecs: jnp.ndarray,  # (N, 8)
    accepts: jnp.ndarray,   # (N,)
    grid_ra: jnp.ndarray,   # (Q, Q)
    grid_dec: jnp.ndarray,  # (Q, Q)
    *,
    psf_kernels: jnp.ndarray | None = None,  # (N, K) rows or (N, K, K) taps
    block_rows: int = 8,
    interpret: bool = True,
):
    """Algorithm 1 in one kernel: projected tiles never touch HBM."""
    n, h, w = pixels.shape
    q = grid_ra.shape[0]
    block_rows = min(block_rows, q)
    if q % block_rows:
        raise ValueError(f"npix {q} must divide block_rows {block_rows}")
    grid = (q // block_rows, n)  # row blocks parallel; images sequential
    in_specs = [
        pl.BlockSpec((1, 8), lambda r, i: (i, 0)),
        pl.BlockSpec((1, 1), lambda r, i: (i, 0)),
        pl.BlockSpec((1, h, w), lambda r, i: (i, 0, 0)),
        pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
        pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
    ]
    operands = [
        wcs_vecs.astype(jnp.float32),
        accepts.astype(jnp.float32).reshape(n, 1),
        pixels.astype(jnp.float32),
        grid_ra,
        grid_dec,
    ]
    kernel_fn = _coadd_fused_kernel
    if psf_kernels is not None and psf_kernels.ndim == 3:
        # Measured-PSF bank: one (Kh, Kw) non-separable tap grid per slot.
        kh, kw = psf_kernels.shape[1], psf_kernels.shape[2]
        in_specs.insert(2, pl.BlockSpec((1, kh, kw), lambda r, i: (i, 0, 0)))
        operands.insert(2, psf_kernels.astype(jnp.float32))
        kernel_fn = _coadd_fused_psf2d_kernel
    elif psf_kernels is not None:
        k_width = psf_kernels.shape[1]
        in_specs.insert(2, pl.BlockSpec((1, k_width), lambda r, i: (i, 0)))
        operands.insert(2, psf_kernels.astype(jnp.float32))
        kernel_fn = _coadd_fused_psf_kernel
    out = pl.pallas_call(
        kernel_fn,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
            pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, q), jnp.float32),
            jax.ShapeDtypeStruct((q, q), jnp.float32),
        ],
        compiler_params=_tpu_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[0], out[1]


# ----- robust-reduce passes (DESIGN.md §11): fused monoidal kernels -----
#
# Sigma-clipped / median stacks decompose into monoidal passes (reducer.py);
# each pass below is `coadd_fused` with a different per-image accumulator,
# sharing the accumulate-innermost grid idiom, the warp, and the in-kernel
# PSF variants — the (N, Q, Q) sample stack still never materializes in HBM.
# Per-pixel operands computed between passes (clip center/radius, histogram
# bounds) arrive as (Q, Q) arrays blocked like the output rows.

def _fused_inputs(pixels, wcs_vecs, accepts, grid_ra, grid_dec, psf_kernels,
                  block_rows):
    """Grid + specs + operand prefix shared by every fused coadd kernel.

    Returns (grid, in_specs, operands, psf_mode, q, block_rows); callers
    append their pass-specific operands/specs after the grids.
    """
    n, h, w = pixels.shape
    q = grid_ra.shape[0]
    block_rows = min(block_rows, q)
    if q % block_rows:
        raise ValueError(f"npix {q} must divide block_rows {block_rows}")
    in_specs = [
        pl.BlockSpec((1, 8), lambda r, i: (i, 0)),
        pl.BlockSpec((1, 1), lambda r, i: (i, 0)),
        pl.BlockSpec((1, h, w), lambda r, i: (i, 0, 0)),
        pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
        pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
    ]
    operands = [
        wcs_vecs.astype(jnp.float32),
        accepts.astype(jnp.float32).reshape(n, 1),
        pixels.astype(jnp.float32),
        grid_ra,
        grid_dec,
    ]
    psf_mode = "none"
    if psf_kernels is not None and psf_kernels.ndim == 3:
        kh, kw = psf_kernels.shape[1], psf_kernels.shape[2]
        in_specs.insert(2, pl.BlockSpec((1, kh, kw), lambda r, i: (i, 0, 0)))
        operands.insert(2, psf_kernels.astype(jnp.float32))
        psf_mode = "2d"
    elif psf_kernels is not None:
        k_width = psf_kernels.shape[1]
        in_specs.insert(2, pl.BlockSpec((1, k_width), lambda r, i: (i, 0)))
        operands.insert(2, psf_kernels.astype(jnp.float32))
        psf_mode = "sep"
    return (q // block_rows, n), in_specs, operands, psf_mode, q, block_rows


def _warped_sample(refs, psf_mode):
    """Shared per-step prologue: unpack refs, PSF-prep, warp one image.

    ``refs`` is the operand-ref prefix [wcs, accept, (kern?), image, gra,
    gdec]; returns (accept scalar, masked value, mask, leftover refs).
    """
    wcs_ref, accept_ref = refs[0], refs[1]
    if psf_mode == "none":
        image_ref, gra_ref, gdec_ref = refs[2], refs[3], refs[4]
        rest = refs[5:]
        img = image_ref[0]
    else:
        kern_ref, image_ref = refs[2], refs[3]
        gra_ref, gdec_ref = refs[4], refs[5]
        rest = refs[6:]
        if psf_mode == "2d":
            img = _convolve_2d_matmul(image_ref[0], kern_ref[0])
        else:
            img = _convolve_sep_matmul(image_ref[0], kern_ref[0, :])
    sx, sy = _sky_to_pixel(gra_ref[...], gdec_ref[...], wcs_ref[0, :])
    vm, m = _bilinear_via_matmul(img, sx, sy)
    return accept_ref[0, 0], vm, m, rest


def _coadd_moments_kernel(*refs, psf_mode):
    """Robust pass 1: weighted moments S0 = Σc, S1 = Σt, S2 = Σt²/c."""
    a, vm, m, rest = _warped_sample(refs, psf_mode)
    s0_ref, s1_ref, s2_ref = rest
    # vm is already mask-scaled; t²/c with binary per-pixel coverage is
    # vm²/m, guarded where the image does not cover the pixel.
    s2c = jnp.where(m > 0, vm * vm / jnp.where(m > 0, m, 1.0), 0.0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        s0_ref[...] = m * a
        s1_ref[...] = vm * a
        s2_ref[...] = s2c * a

    @pl.when(i > 0)
    def _accum():
        s0_ref[...] += m * a
        s1_ref[...] += vm * a
        s2_ref[...] += s2c * a


def _coadd_clip_kernel(*refs, psf_mode):
    """Robust final pass: accumulate only samples inside |x - center| <= r.

    ``center``/``thresh`` are fixed (Q, Q) operands from the completed
    moments (or histogram) pass, blocked identically to the output rows.
    """
    a, vm, m, rest = _warped_sample(refs, psf_mode)
    center_ref, thresh_ref, coadd_ref, depth_ref = rest
    # Division-free form, matching reducer.clip_local bit-for-bit:
    # |vm - m*center| <= m*thresh  ==  |vm/m - center| <= thresh for m > 0.
    keep = ((m > 0)
            & (jnp.abs(vm - m * center_ref[...]) <= m * thresh_ref[...])
            ).astype(vm.dtype)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        coadd_ref[...] = vm * keep * a
        depth_ref[...] = m * keep * a

    @pl.when(i > 0)
    def _accum():
        coadd_ref[...] += vm * keep * a
        depth_ref[...] += m * keep * a


def _coadd_hist_kernel(*refs, psf_mode, nbins):
    """Median round 1: coverage-weighted binapprox histogram.

    Output block is (nbins, block_rows, q) — every step owns the full bin
    axis of its row block, and the static loop over bins keeps the scatter
    as nbins dense masked accumulations (no TPU gather needed).
    """
    a, vm, m, rest = _warped_sample(refs, psf_mode)
    lo_ref, inv_w_ref, hist_ref = rest
    x = jnp.where(m > 0, vm / jnp.where(m > 0, m, 1.0), 0.0)
    b = jnp.clip(jnp.floor((x - lo_ref[...]) * inv_w_ref[...]), 0, nbins - 1)
    wgt = m * a
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        for j in range(nbins):
            hist_ref[j] = wgt * (b == j).astype(wgt.dtype)

    @pl.when(i > 0)
    def _accum():
        for j in range(nbins):
            hist_ref[j] += wgt * (b == j).astype(wgt.dtype)


def coadd_moments(
    pixels: jnp.ndarray,    # (N, H, W)
    wcs_vecs: jnp.ndarray,  # (N, 8)
    accepts: jnp.ndarray,   # (N,)
    grid_ra: jnp.ndarray,   # (Q, Q)
    grid_dec: jnp.ndarray,  # (Q, Q)
    *,
    psf_kernels: jnp.ndarray | None = None,
    block_rows: int = 8,
    interpret: bool = True,
):
    """Fused robust pass 1 -> (S0, S1, S2) moment maps, one kernel."""
    grid, in_specs, operands, psf_mode, q, block_rows = _fused_inputs(
        pixels, wcs_vecs, accepts, grid_ra, grid_dec, psf_kernels, block_rows
    )
    out = pl.pallas_call(
        functools.partial(_coadd_moments_kernel, psf_mode=psf_mode),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_rows, q), lambda r, i: (r, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((q, q), jnp.float32)] * 3,
        compiler_params=_tpu_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[0], out[1], out[2]


def coadd_clip(
    pixels: jnp.ndarray,
    wcs_vecs: jnp.ndarray,
    accepts: jnp.ndarray,
    grid_ra: jnp.ndarray,
    grid_dec: jnp.ndarray,
    center: jnp.ndarray,    # (Q, Q) clip center (mean or binapprox median)
    thresh: jnp.ndarray,    # (Q, Q) clip radius
    *,
    psf_kernels: jnp.ndarray | None = None,
    block_rows: int = 8,
    interpret: bool = True,
):
    """Fused robust final pass -> (coadd, depth) of surviving samples."""
    grid, in_specs, operands, psf_mode, q, block_rows = _fused_inputs(
        pixels, wcs_vecs, accepts, grid_ra, grid_dec, psf_kernels, block_rows
    )
    in_specs += [
        pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
        pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
    ]
    operands += [center.astype(jnp.float32), thresh.astype(jnp.float32)]
    out = pl.pallas_call(
        functools.partial(_coadd_clip_kernel, psf_mode=psf_mode),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_rows, q), lambda r, i: (r, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((q, q), jnp.float32)] * 2,
        compiler_params=_tpu_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[0], out[1]


def coadd_hist(
    pixels: jnp.ndarray,
    wcs_vecs: jnp.ndarray,
    accepts: jnp.ndarray,
    grid_ra: jnp.ndarray,
    grid_dec: jnp.ndarray,
    lo: jnp.ndarray,        # (Q, Q) binapprox lower bound (mu - sigma)
    inv_w: jnp.ndarray,     # (Q, Q) reciprocal bin width
    *,
    nbins: int = 16,
    psf_kernels: jnp.ndarray | None = None,
    block_rows: int = 8,
    interpret: bool = True,
):
    """Fused median round 1 -> (nbins, Q, Q) weighted binapprox histogram."""
    grid, in_specs, operands, psf_mode, q, block_rows = _fused_inputs(
        pixels, wcs_vecs, accepts, grid_ra, grid_dec, psf_kernels, block_rows
    )
    in_specs += [
        pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
        pl.BlockSpec((block_rows, q), lambda r, i: (r, 0)),
    ]
    operands += [lo.astype(jnp.float32), inv_w.astype(jnp.float32)]
    out = pl.pallas_call(
        functools.partial(_coadd_hist_kernel, psf_mode=psf_mode, nbins=nbins),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((nbins, block_rows, q), lambda r, i: (0, r, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((nbins, q, q), jnp.float32)],
        compiler_params=_tpu_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[0]


# ----- brick mosaic: scatter cached tiles into a query canvas (§9) -----
def _mosaic_kernel(off_ref, tile_ref, cov_ref, coadd_ref, depth_ref, *, bh, bw):
    """One grid step merges one brick tile at its dynamic (row, col) offset.

    The outputs map the full canvas on every step (constant index_map), so
    the accumulate-across-grid-steps idiom of `_coadd_fused_kernel` applies:
    zero the canvas on the first step, then add each tile through a dynamic
    slice.  Bricks never overlap, so add == write — but accumulation keeps
    the merge the same reduce monoid as the XLA `reducer.mosaic_tiles`.
    """
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        coadd_ref[...] = jnp.zeros_like(coadd_ref)
        depth_ref[...] = jnp.zeros_like(depth_ref)

    r = off_ref[0, 0]
    c = off_ref[0, 1]
    coadd_ref[pl.ds(r, bh), pl.ds(c, bw)] += tile_ref[0]
    depth_ref[pl.ds(r, bh), pl.ds(c, bw)] += cov_ref[0]


def mosaic_bricks(
    tiles: jnp.ndarray,    # (B, bh, bw) cached brick coadds
    covs: jnp.ndarray,     # (B, bh, bw) weight (depth) maps
    offsets: jnp.ndarray,  # (B, 2) int32 (row, col) canvas positions
    npix: int,
    *,
    interpret: bool = True,
):
    """(npix, npix) coadd + depth mosaicked from cached brick tiles."""
    n, bh, bw = tiles.shape
    out = pl.pallas_call(
        functools.partial(_mosaic_kernel, bh=bh, bw=bw),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda b: (b, 0)),
            pl.BlockSpec((1, bh, bw), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, bh, bw), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((npix, npix), lambda b: (0, 0)),
            pl.BlockSpec((npix, npix), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npix, npix), jnp.float32),
            jax.ShapeDtypeStruct((npix, npix), jnp.float32),
        ],
        # Tiles accumulate into one canvas: the single grid dim is sequential.
        compiler_params=_tpu_params(("arbitrary",)),
        interpret=interpret,
    )(offsets.astype(jnp.int32), tiles.astype(jnp.float32),
      covs.astype(jnp.float32))
    return out[0], out[1]
