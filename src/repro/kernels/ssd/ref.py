"""Pure-jnp oracle for the Mamba-2 SSD recurrence (per (batch, head) slice).

State-space recurrence with scalar-identity A (Mamba-2 / SSD, arXiv:2405.21060):

    S_t = a_t * S_{t-1} + B_t x_t^T        S in R^{N x P}
    y_t = C_t^T S_t

a_t = exp(dt_t * A) in (0, 1]; B_t, C_t in R^N; x_t in R^P.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(a, B, C, x):
    """a: (T,), B: (T,N), C: (T,N), x: (T,P) -> y: (T,P). Step-by-step scan."""
    n = B.shape[1]
    p = x.shape[1]

    def step(S, inp):
        a_t, b_t, c_t, x_t = inp
        S = a_t * S + jnp.outer(b_t, x_t)
        y_t = c_t @ S
        return S, y_t

    S0 = jnp.zeros((n, p), jnp.float32)
    _, y = jax.lax.scan(step, S0, (a, B, C, x))
    return y


def ssd_batched_ref(a, B, C, x):
    """a: (Bt,T,H), B/C: (Bt,T,N), x: (Bt,T,H,P) -> (Bt,T,H,P).

    B and C are shared across heads (Mamba-2 convention).
    """

    def per_batch(a_b, B_b, C_b, x_b):
        def per_head(a_h, x_h):
            return ssd_scan_ref(a_h, B_b, C_b, x_h)

        return jax.vmap(per_head, in_axes=(1, 1), out_axes=1)(a_b, x_b)

    return jax.vmap(per_batch)(a, B, C, x)
