"""Jitted batched wrapper for the SSD kernel (B/C shared across heads)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd.ssd import ssd_chunked


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(a, B, C, x, chunk=64, interpret=True):
    """a: (Bt,T,H), B/C: (Bt,T,N), x: (Bt,T,H,P) -> (Bt,T,H,P)."""

    def per_batch(a_b, B_b, C_b, x_b):
        def per_head(a_h, x_h):
            return ssd_chunked(a_h, B_b, C_b, x_h, chunk=chunk, interpret=interpret)

        return jax.vmap(per_head, in_axes=(1, 1), out_axes=1)(a_b, x_b)

    return jax.vmap(per_batch)(a, B, C, x)
