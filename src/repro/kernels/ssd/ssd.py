"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality form).

The SSD insight (arXiv:2405.21060): the linear recurrence splits into
chunk-local *quadratic* attention-like work (MXU matmuls) plus a tiny
sequential state carry between chunks.  TPU mapping:

  grid = (T / L,) iterated sequentially ("arbitrary"); the inter-chunk state
  S (N x P) lives in VMEM scratch and persists across grid steps — the
  sequential part touches only N*P floats per chunk while all O(L^2) work is
  dense matmul.

Per chunk (inclusive decay cumprods alpha_i = prod_{j<=i} a_j, computed in
log space for stability; a in (0,1] so every ratio below is <= 1):

  intra:  Y += (M o (C B^T)) X        M[i,j] = alpha_i / alpha_j, j <= i
  inter:  Y += alpha o (C S_in)
  carry:  S_out = alpha_{L-1} S_in + B_w^T X,   B_w[j] = (alpha_{L-1}/alpha_j) B_j
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, b_ref, c_ref, x_ref, y_ref, s_ref, *, chunk):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[...]           # (L, 1) decay in (0, 1]
    B = b_ref[...]           # (L, N)
    C = c_ref[...]           # (L, N)
    X = x_ref[...]           # (L, P)
    S = s_ref[...]           # (N, P) carried state

    log_a = jnp.log(a)                       # (L, 1)
    cum = jnp.cumsum(log_a, axis=0)          # inclusive log alpha
    # M[i, j] = exp(cum_i - cum_j) for j <= i else 0
    li = cum                                  # (L, 1)
    lj = cum.reshape(1, chunk)                # (1, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(jj <= ii, jnp.exp(li - lj), 0.0)       # (L, L)

    cb = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (L, L)
    y_intra = jnp.dot(m * cb, X, preferred_element_type=jnp.float32)

    alpha = jnp.exp(cum)                                  # (L, 1)
    y_inter = alpha * jnp.dot(C, S, preferred_element_type=jnp.float32)

    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)

    # State carry: S_out = alpha_last * S + sum_j (alpha_last/alpha_j) B_j X_j^T
    alpha_last = jnp.exp(cum[chunk - 1, 0])
    w = jnp.exp(cum[chunk - 1, 0] - cum)                  # (L, 1)
    s_ref[...] = alpha_last * S + jnp.dot(
        (B * w).T, X, preferred_element_type=jnp.float32
    )


def ssd_chunked(a, B, C, x, *, chunk=64, interpret=True):
    """One (batch, head) slice. a: (T,), B/C: (T,N), x: (T,P) -> y (T,P)."""
    t = a.shape[0]
    n = B.shape[1]
    p = x.shape[1]
    chunk = min(chunk, t)
    if t % chunk:
        raise ValueError(f"T={t} must divide chunk={chunk}")
    grid = (t // chunk,)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, 1), lambda c: (c, 0)),
            pl.BlockSpec((chunk, n), lambda c: (c, 0)),
            pl.BlockSpec((chunk, n), lambda c: (c, 0)),
            pl.BlockSpec((chunk, p), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, p), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(a.reshape(t, 1).astype(jnp.float32), B, C, x)
