"""Jitted GQA flash-attention wrapper with custom_vjp.

Forward: Pallas flash kernel (vmapped over batch x q-heads; kv heads are
index-mapped for GQA so no repeat materializes).  Backward: recompute with
the jnp reference and differentiate through it — the standard
kernel-forward / XLA-backward bring-up path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attention.flash import flash_attention_single
from repro.kernels.attention.ref import mha_ref


@partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def flash_attention(
    q, k, v, causal=True, window=None, block_q=128, block_k=128, interpret=True, scale=None
):
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) -> (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv

    def per_head(qh, kh, vh):
        return flash_attention_single(
            qh, kh, vh, causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )

    # GQA: gather the kv head for each q head (no repeat in HBM).
    kv_idx = jnp.arange(hq) // group
    k_g = k[:, kv_idx]
    v_g = v[:, kv_idx]
    return jax.vmap(jax.vmap(per_head))(q, k_g, v_g)


def _fwd(q, k, v, causal, window, block_q, block_k, interpret, scale):
    out = flash_attention(q, k, v, causal, window, block_q, block_k, interpret, scale)
    return out, (q, k, v)


def _bwd(causal, window, block_q, block_k, interpret, scale, res, g):
    q, k, v = res

    def ref_fn(q, k, v):
        return mha_ref(q, k, v, causal=causal, window=window, scale=scale)

    _, vjp = jax.vjp(ref_fn, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
