"""Pallas TPU flash attention (forward) with online softmax.

Classic FlashAttention blocking adapted to TPU memory hierarchy: the grid is
(q_blocks, kv_blocks) with the kv dimension innermost ("arbitrary"
semantics); running max / denominator / accumulator live in VMEM scratch and
persist across kv grid steps; the output block is written on the last kv
step.  Q/K/V blocks stream HBM->VMEM via BlockSpecs; block sizes default to
MXU-aligned (128, 128).

Causal + sliding-window masking is applied in-kernel.  GQA is handled by the
wrapper (kv head index = q head index // group) so the kernel itself only
sees one (batch, head) slice — vmapped on the outside, which Pallas turns
into extra grid dimensions.

Backward: `ops.flash_attention` wraps this in a custom_vjp whose backward
pass recomputes attention with the jnp reference — the standard
"kernel-forward, XLA-backward" migration path; a hand-written bwd kernel is
a further optimization documented in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, causal, window, block_q, block_k, seq_len
):
    qi = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]  # (block_q, d)
    k = k_ref[...]  # (block_k, d)
    v = v_ref[...]  # (block_k, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]          # (bq, 1)
    l_prev = l_ref[...]          # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
    l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] / jnp.where(l == 0, 1.0, l)).astype(o_ref.dtype)


def flash_attention_single(
    q, k, v, *, causal=True, window=None, scale=None,
    block_q=128, block_k=128, interpret=True,
):
    """One (seq, head_dim) attention slice. q,k,v: (S, D) -> (S, D)."""
    s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} must divide blocks ({block_q},{block_k})")
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    grid = (s // block_q, s // block_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        seq_len=s,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
        ],
        interpret=interpret,
    )(q, k, v)
