"""Pure-jnp oracle for flash attention (GQA, optional causal/sliding window)."""

from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0.

    Returns (B, Hq, S, D). fp32 softmax accumulation.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
