"""Checkpointing: sharded-npz + manifest, atomic commit, async save, GC.

Restart semantics (paper §3's failures-are-the-norm stance, training edition):
  * SAVE: leaves are written to ``step_N.tmp/`` then the directory is
    renamed — a crash mid-save can never corrupt the latest checkpoint;
  * manifest.json records step + tree structure; ``latest`` resolution scans
    committed directories only;
  * async mode does the device->host gather synchronously (cheap) and the
    file I/O on a background thread (joined before the next save or exit);
  * keep_last garbage-collects old steps.

Leaves are stored by flattened tree path in a single .npz per checkpoint —
at real multi-pod scale this becomes one file per host (the writer already
receives per-host slices via `jax.device_get`).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ---
    def save(self, step: int, state: Dict[str, Any]):
        flat = {name: _flatten(tree) for name, tree in state.items()}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat: Dict[str, Dict[str, np.ndarray]]):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, leaves in flat.items():
            np.savez(os.path.join(tmp, f"{name}.npz"), **leaves)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "groups": sorted(flat)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---------------------------------------------------------- restore ---
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, templates: Dict[str, Any]) -> Tuple[int, Dict]:
        base = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["step"] == step
        out = {}
        for name, template in templates.items():
            with np.load(os.path.join(base, f"{name}.npz")) as z:
                flat = {k: z[k] for k in z.files}
            out[name] = _unflatten(template, flat)
        return step, out
