"""Production meshes.

A v5e pod is 16x16 = 256 chips; the multi-pod run is 2 pods = 512.  The
``pod`` axis is the DCN-crossing dimension: only batch (data parallelism)
is sharded over it, so cross-pod traffic is one gradient all-reduce per
step while all tensor-parallel collectives stay on intra-pod ICI.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dry-run only)"
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for CPU integration tests (8 forced host devices)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
