"""Fault-tolerant training driver.

End-to-end: synthetic corpus -> packed shards (sequence-file style) ->
deterministic pipeline -> jit'd train step on a device mesh -> periodic
atomic checkpoints -> restart-on-failure.

Failure drill: ``--crash-at-step N`` raises after committing step N's work,
simulating a node loss; re-running the same command with the same
--run-dir resumes from the latest checkpoint and (by the pipeline's
pure-function-of-step contract) consumes exactly the batches it would have
seen without the crash.  `tests/test_train_loop.py` asserts bitwise-equal
final losses for crashed+resumed vs uninterrupted runs.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --steps 30 --global-batch 8 --seq-len 64 --run-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.packing import pack_documents, synthetic_corpus
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch.specs import make_train_step
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.schedule import warmup_cosine


def build_everything(args):
    from repro.configs.registry import get_config, reduced_config

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.vocab:
        cfg = dataclasses.replace(cfg, vocab_size=args.vocab)
    model = build_model(cfg)

    docs, srcs = synthetic_corpus(
        n_docs=args.n_docs, vocab=cfg.vocab_size, seed=args.data_seed
    )
    shards = pack_documents(docs, srcs, shard_len=max(args.seq_len * 4, 512))
    pipe = TokenPipeline(
        shards,
        PipelineConfig(args.global_batch, args.seq_len, seed=args.data_seed),
    )

    ocfg = AdamWConfig(
        lr=args.lr, schedule=warmup_cosine(args.warmup, args.steps)
    )
    step_fn = jax.jit(make_train_step(model, ocfg), donate_argnums=(0, 1))
    return cfg, model, pipe, step_fn


def add_batch_extras(batch, cfg, rng):
    if cfg.family == "encdec":
        batch["enc_frames"] = rng.standard_normal(
            (batch["tokens"].shape[0], cfg.encoder_seq, cfg.d_model), np.float32
        )
    if cfg.family == "vlm":
        batch["img_embeds"] = rng.standard_normal(
            (batch["tokens"].shape[0], cfg.n_image_tokens, cfg.d_model), np.float32
        )
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--n-docs", type=int, default=256)
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--init-seed", type=int, default=0)
    ap.add_argument("--run-dir", default="/tmp/repro_run")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--crash-at-step", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg, model, pipe, step_fn = build_everything(args)
    ckpt = CheckpointManager(os.path.join(args.run_dir, "ckpt"))

    params = model.init(jax.random.PRNGKey(args.init_seed))
    opt_state = adamw_init(params)
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        _, state = ckpt.restore(latest, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = latest
        print(f"[resume] from step {start}", flush=True)

    extras_rng = np.random.default_rng(1234)
    losses = []
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = add_batch_extras(pipe.batch_at(step), cfg, extras_rng)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"step {step:5d} loss {loss:.4f} gnorm "
                f"{float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e}",
                flush=True,
            )
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
        if args.crash_at_step == step:
            ckpt.wait()
            raise SystemExit(f"[drill] injected crash after step {step}")
    ckpt.wait()
    dt = time.perf_counter() - t0

    out = {
        "arch": cfg.name,
        "steps": args.steps,
        "final_loss": losses[-1] if losses else None,
        "losses": losses,
        "wall_s": dt,
        "tokens_per_s": args.global_batch * args.seq_len * max(len(losses), 1) / dt,
    }
    os.makedirs(args.run_dir, exist_ok=True)
    with open(os.path.join(args.run_dir, "result.json"), "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps({k: v for k, v in out.items() if k != "losses"}, indent=1))


if __name__ == "__main__":
    main()
