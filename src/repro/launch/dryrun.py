import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  1. PRODUCTION compile (scan-over-layers, full depth) on the requested
     mesh: proves the sharding config is coherent, records
     memory_analysis() (fits-on-chip evidence) and the collective schedule.
  2. COST PROBES (single-pod only): unrolled reduced-depth variants whose
     cost_analysis deltas give exact per-layer FLOPs / bytes / collective
     traffic, scaled analytically to full depth (HloCostAnalysis counts
     while-loop bodies once — see `repro.analysis.hlo`).

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out-dir experiments/dryrun
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp


def _jit_cell(cell):
    return jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )


def _compile_cell(cell) -> Dict:
    t0 = time.perf_counter()
    lowered = _jit_cell(cell).lower(*cell.args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    from repro.analysis import hlo as hlo_mod

    text = compiled.as_text()
    coll = hlo_mod.collective_stats(text)
    return {
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collectives": coll,
        "hlo_bytes": len(text),
    }


def _probe_cfgs(cfg):
    """Reduced-depth unrolled variants + the scale rule (see module doc)."""
    r = dataclasses.replace
    base = dict(scan_layers=False, force_dense_attn=True)
    fam = cfg.family
    if fam in ("dense", "moe", "ssm"):
        return {
            "a": r(cfg, n_layers=1, **base),
            "b": r(cfg, n_layers=2, **base),
        }, {"layers": cfg.n_layers}
    if fam == "vlm":
        per = cfg.cross_attn_period
        return {
            "a": r(cfg, n_layers=per, **base),
            "b": r(cfg, n_layers=2 * per, **base),
        }, {"groups": cfg.n_layers // per}
    if fam == "hybrid":
        per = cfg.shared_attn_period
        groups = cfg.n_layers // per
        rem = cfg.n_layers - groups * per
        probes = {
            "a": r(cfg, n_layers=per, **base),
            "b": r(cfg, n_layers=2 * per, **base),
        }
        if rem:
            probes["c"] = r(cfg, n_layers=per + rem, **base)
        return probes, {"groups": groups, "rem": rem}
    if fam == "encdec":
        return {
            "a": r(cfg, n_layers=1, n_encoder_layers=1, **base),
            "b": r(cfg, n_layers=1, n_encoder_layers=2, **base),
            "c": r(cfg, n_layers=2, n_encoder_layers=1, **base),
        }, {"enc": cfg.n_encoder_layers, "dec": cfg.n_layers}
    raise ValueError(fam)


def _scale_costs(fam: str, probes: Dict[str, Dict], info: Dict) -> Dict:
    """Combine probe costs (flops/bytes/collective link bytes) to full depth."""

    def extract(p):
        from repro.analysis.hlo import total_link_bytes

        return {
            "flops": p["cost"]["flops"],
            "bytes": p["cost"]["bytes_accessed"],
            "coll": total_link_bytes(p["collectives"]),
        }

    a = extract(probes["a"])
    b = extract(probes["b"])
    out = {}
    for key in ("flops", "bytes", "coll"):
        if fam in ("dense", "moe", "ssm"):
            per_layer = b[key] - a[key]
            out[key] = a[key] + (info["layers"] - 1) * per_layer
        elif fam == "vlm":
            per_group = b[key] - a[key]
            out[key] = a[key] + (info["groups"] - 1) * per_group
        elif fam == "hybrid":
            per_group = b[key] - a[key]
            out[key] = a[key] + (info["groups"] - 1) * per_group
            if info["rem"]:
                c = extract(probes["c"])
                out[key] += c[key] - a[key]
        elif fam == "encdec":
            c = extract(probes["c"])
            per_enc = b[key] - a[key]
            per_dec = c[key] - a[key]
            out[key] = a[key] + (info["enc"] - 1) * per_enc + (info["dec"] - 1) * per_dec
        else:
            raise ValueError(fam)
    return out


def _parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def run_cell(
    arch: str, shape_name: str, mesh_kind: str, probes: bool = True,
    overrides: Optional[Dict] = None, skip_production: bool = False,
) -> Dict:
    from repro.configs.base import SHAPE_BY_NAME
    from repro.configs.registry import get_config
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = S.shape_applicable(cfg, shape)
    result: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not ok:
        result["skipped"] = why
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    with mesh, jax.set_mesh(mesh):
        if not skip_production:
            cell = S.build_cell(cfg, shape, mesh)
            result["production"] = _compile_cell(cell)

        if probes and mesh_kind == "single":
            probe_cfgs, info = _probe_cfgs(cfg)
            probe_results = {}
            for name, pcfg in probe_cfgs.items():
                pcell = S.build_cell(pcfg, shape, mesh)
                probe_results[name] = _compile_cell(pcell)
            result["probes"] = probe_results
            result["scaled_cost"] = _scale_costs(cfg.family, probe_results, info)
            result["probe_info"] = info
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    ap.add_argument("--skip-production", action="store_true",
                    help="probes only (fast §Perf iteration)")
    args = ap.parse_args()

    if args.all:
        # Subprocess per cell: isolates compiler memory, survives one bad cell.
        from repro.configs.base import SHAPES
        from repro.configs.registry import ARCH_IDS

        os.makedirs(args.out_dir, exist_ok=True)
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mesh_kind in meshes:
                    out = os.path.join(
                        args.out_dir, f"{arch}__{shape.name}__{mesh_kind}.json"
                    )
                    if os.path.exists(out):
                        print(f"[skip] {out} exists", flush=True)
                        continue
                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape.name,
                        "--mesh", mesh_kind, "--out", out,
                    ]
                    if args.no_probes:
                        cmd.append("--no-probes")
                    print(f"[run ] {arch} x {shape.name} x {mesh_kind}", flush=True)
                    rc = subprocess.run(cmd).returncode
                    if rc != 0:
                        failures.append((arch, shape.name, mesh_kind))
                        print(f"[FAIL] {arch} x {shape.name} x {mesh_kind}", flush=True)
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    t0 = time.perf_counter()
    try:
        overrides = dict(_parse_override(kv) for kv in args.set)
        res = run_cell(args.arch, args.shape, args.mesh,
                       probes=not args.no_probes, overrides=overrides,
                       skip_production=args.skip_production)
    except Exception:
        res = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "error": traceback.format_exc(),
        }
    res["wall_s"] = time.perf_counter() - t0
    blob = json.dumps(res, indent=1, default=float)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(blob)
    print(blob[:2000])
    if "error" in res:
        sys.exit(1)


if __name__ == "__main__":
    main()
