"""Batched serving driver: prefill a prompt batch, decode N tokens.

Greedy decoding against the prefill-built cache; reports prefill and
per-token decode throughput.  (CPU demo uses reduced configs; the same
prefill/decode steps are what the dry-run lowers at the assigned shapes.)

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config, reduced_config

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    b, s = args.batch, args.prompt_len
    max_len = s + args.gen
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_image_tokens, cfg.d_model)), jnp.float32
        )

    prefill = jax.jit(lambda p, bt: model.prefill(p, bt, max_len))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t1 = time.perf_counter()

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [np.asarray(tok)]
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t2 = time.perf_counter()

    gen = np.concatenate(generated, axis=1)
    out = {
        "arch": cfg.name,
        "prefill_s": t1 - t0,
        "decode_s": t2 - t1,
        "decode_tok_per_s": b * (args.gen - 1) / max(t2 - t1, 1e-9),
        "sample_tokens": gen[0][:10].tolist(),
    }
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
