"""Coadd-serving CLI: demo and seeded concurrency drill for `CoaddService`.

Replaces the dormant LLM-decode driver this file used to hold: serving here
means the paper's workload — concurrent multi-tenant coadd queries through
the async front end (`repro.core.serve`, DESIGN.md §10), coalesced into the
engine's batched one-dispatch scans.

Demo:
  PYTHONPATH=src python -m repro.launch.serve --clients 16

Drill (CI `serve-smoke`): same run, then assert the serving contract —
every response bitwise-equal to a direct `engine.run`, coalesce factor
above 1, zero requests shed below the admission limit — and exit nonzero
on any violation:
  PYTHONPATH=src python -m repro.launch.serve --clients 16 --drill
"""

from __future__ import annotations

import argparse
import asyncio
import json
import time

import numpy as np

from repro.core import (
    CoaddEngine,
    CoaddQuery,
    CoaddService,
    SurveyConfig,
    make_survey,
)

DRILL_SURVEY = SurveyConfig(
    n_runs=4, n_camcols=4, n_bands=3, n_fields=6,
    height=24, width=24, n_sources=150, seed=9,
)


def drill_queries(seed: int, clients: int, pool: int):
    """Seeded multi-tenant workload: a skewed draw over a mixed query pool.

    The pool interleaves cheap quarter-degree-ish queries with full-stripe
    monsters at a different npix (so the two classes neither share a
    coalesce group nor a cost class), and clients draw from it with
    popularity skew — repeats are the realistic case the result cache and
    in-flight merging exist for.
    """
    rng = np.random.default_rng(seed)
    qs = []
    for i in range(pool):
        if i % 4 == 3:  # monster: whole footprint, larger grid
            qs.append(CoaddQuery(
                band="r", ra_bounds=(37.0, 38.5), dec_bounds=(-0.8, 0.8),
                npix=96,
            ))
        else:  # cheap: small box sliding along RA
            lo = 37.1 + 0.15 * i
            qs.append(CoaddQuery(
                band="r", ra_bounds=(lo, lo + 0.4), dec_bounds=(-0.3, 0.3),
                npix=64,
            ))
    # Zipf-ish popularity: earlier pool entries are hotter.
    w = 1.0 / np.arange(1, pool + 1)
    picks = rng.choice(pool, size=clients, p=w / w.sum())
    return [qs[int(i)] for i in picks]


async def _run_service(engine, queries, args):
    svc = CoaddService(
        engine,
        method=args.method,
        max_queue=args.max_queue,
        max_batch=args.max_batch,
    )
    # Queue the whole burst before starting the dispatcher: the recorded-
    # burst replay pattern, and what makes the drill's coalescing
    # deterministic rather than racing the first drain.
    tasks = [
        asyncio.ensure_future(svc.submit(q, tenant=f"t{i % 4}"))
        for i, q in enumerate(queries)
    ]
    while svc.queue_depth < len(queries):
        await asyncio.sleep(0.005)
    t0 = time.perf_counter()
    async with svc:
        results = await asyncio.gather(*tasks)
    wall = time.perf_counter() - t0
    return svc, results, wall


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--pool", type=int, default=8,
                    help="distinct queries the clients draw from")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="sql_structured")
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--drill", action="store_true",
                    help="assert the serving contract; exit 1 on violation")
    args = ap.parse_args(argv)

    survey = make_survey(DRILL_SURVEY)
    engine = CoaddEngine(survey, pack_capacity=16)
    queries = drill_queries(args.seed, args.clients, args.pool)

    # Serial reference: each distinct query straight through the engine.
    serial = {}
    t0 = time.perf_counter()
    for q in queries:
        if q not in serial:
            serial[q] = engine.run(q, args.method)
    t_serial_unique = time.perf_counter() - t0

    svc, results, wall = asyncio.run(_run_service(engine, queries, args))

    snap = svc.stats.snapshot()
    mismatched = sum(
        not (np.array_equal(r.coadd, serial[q].coadd)
             and np.array_equal(r.depth, serial[q].depth))
        for q, r in zip(queries, results)
    )
    out = {
        "clients": args.clients,
        "distinct": len(serial),
        "wall_s": round(wall, 4),
        "serial_unique_s": round(t_serial_unique, 4),
        "bitwise_mismatches": mismatched,
        "stats": snap,
    }
    print(json.dumps(out, indent=1))

    if args.drill:
        failures = []
        if mismatched:
            failures.append(
                f"{mismatched}/{args.clients} responses differ bitwise "
                f"from direct engine.run"
            )
        if not svc.stats.coalesce_factor > 1.0:
            failures.append(
                f"coalesce factor {svc.stats.coalesce_factor:.2f} <= 1"
            )
        if svc.stats.shed != 0:
            failures.append(
                f"{svc.stats.shed} requests shed below the admission limit"
            )
        if svc.stats.completed != args.clients:
            failures.append(
                f"completed {svc.stats.completed} != {args.clients}"
            )
        if failures:
            for f in failures:
                print(f"DRILL FAIL: {f}")
            raise SystemExit(1)
        print(f"DRILL OK: {args.clients} clients, "
              f"{snap['dispatches']} dispatches, "
              f"coalesce {snap['coalesce_factor']}x, 0 shed, bitwise clean")
    return out


if __name__ == "__main__":
    main()
