"""Step builders + ShapeDtypeStruct input specs for every (arch x shape).

``input_specs`` returns weak-type-correct, shardable stand-ins — no device
allocation anywhere (the dry-run contract).  Modality frontends are stubs
per the assignment: whisper gets precomputed frame embeddings, the VLM gets
precomputed patch embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPE_BY_NAME, ModelConfig, ShapeConfig
from repro.distributed import sharding as shard_rules
from repro.models.model import LM, build_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def make_train_step(
    model: LM,
    ocfg: Optional[AdamWConfig] = None,
    compute_pspecs=None,
) -> Callable:
    """Train step. With ``compute_pspecs`` (ZeRO-1 mode) the fp32 masters
    stay (data x model)-sharded while a bf16 working copy is materialized
    ONCE per step with model-only sharding — one all-gather per step instead
    of per-layer fp32 FSDP gathers; gradient cotangents reduce-scatter back
    to the master sharding automatically."""
    ocfg = ocfg or AdamWConfig()
    from jax.sharding import PartitionSpec as P

    def train_step(params, opt_state, batch):
        def loss_fn(masters):
            if compute_pspecs is not None:
                cast_c = lambda p, sp: jax.lax.with_sharding_constraint(  # noqa: E731
                    p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p, sp
                )
                compute = jax.tree.map(
                    cast_c, masters, compute_pspecs,
                    is_leaf=lambda x: isinstance(x, P),
                )
            else:
                compute = masters
            return model.loss(compute, batch)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params_new, opt_state, metrics = adamw_update(grads, opt_state, params, ocfg)
        metrics["loss"] = loss
        return params_new, opt_state, metrics

    return train_step


def make_prefill_step(model: LM, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_decode_step(model: LM) -> Callable:
    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return decode_step


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["img_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if shape.kind == "prefill":
        batch.pop("labels")
    return batch


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    bspec2 = shard_rules.batch_pspec(mesh, shape.global_batch, extra_dims=1,
                                     pure_dp=cfg.pure_dp)
    bspec3 = shard_rules.batch_pspec(mesh, shape.global_batch, extra_dims=2,
                                     pure_dp=cfg.pure_dp)
    out = {}
    for k in ("tokens", "labels"):
        out[k] = NamedSharding(mesh, bspec2)
    if cfg.family == "encdec":
        out["enc_frames"] = NamedSharding(mesh, bspec3)
    if cfg.family == "vlm":
        out["img_embeds"] = NamedSharding(mesh, bspec3)
    if shape.kind == "prefill":
        out.pop("labels")
    return out


def cache_shardings(cache_shape, mesh: Mesh, batch: int):
    """Per-leaf NamedShardings for a stacked cache tree.

    KV leaves (L,B,T,H,Dh) shard T over model (distributed KV / flash-decode)
    and B over the data axes; SSM state leaves shard their channel dims over
    model.
    """

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        nd = leaf.ndim
        msize = mesh.shape.get("model", 1)
        if name in ("k", "v", "cross_k", "cross_v") and nd == 5:
            return shard_rules.cache_pspec(mesh, batch, leaf.shape, seq_axis=2)
        if name == "conv" and nd == 4:  # (L,B,W-1,C)
            b_axes = shard_rules._dp_if_divisible(mesh, batch)
            ch = "model" if leaf.shape[3] % msize == 0 else None
            return P(None, b_axes, None, ch)
        if name == "ssm" and nd == 5:  # (L,B,H,N,P)
            b_axes = shard_rules._dp_if_divisible(mesh, batch)
            hd = "model" if leaf.shape[2] % msize == 0 else None
            return P(None, b_axes, hd, None, None)
        b_axes = shard_rules._dp_if_divisible(mesh, batch)
        sp = [None] * nd
        if nd >= 2:
            sp[1] = b_axes
        return P(*sp)

    specs = jax.tree_util.tree_map_with_path(spec, cache_shape)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    fn: Callable
    args: Tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...]


def build_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, ocfg: Optional[AdamWConfig] = None
) -> CellSpec:
    act_axes = tuple(shard_rules.dp_axes(mesh))
    if cfg.pure_dp and "model" in mesh.shape:
        act_axes = act_axes + ("model",)
    elif cfg.moe_impl == "shard_map" and "model" in mesh.shape:
        # widen the MoE token sharding to the model axis when it divides:
        # the routed FFN then runs 256-way data-parallel.
        import numpy as _np
        total = int(_np.prod([mesh.shape[a] for a in act_axes + ("model",)]))
        if shape.global_batch % total == 0:
            act_axes = act_axes + ("model",)
    cfg = dataclasses.replace(cfg, act_shard_axes=act_axes)
    model = build_model(cfg)
    rng = sds((2,), jnp.uint32)  # PRNGKey stand-in (threefry key data)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = shard_rules.param_pspecs(params_shape, mesh, pure_dp=cfg.pure_dp)
    pshard = shard_rules.named_shardings(pspecs, mesh)

    batch = batch_specs(cfg, shape)
    bshard = batch_shardings(cfg, shape, mesh)

    if shape.kind == "train":
        compute_pspecs = (
            shard_rules.strip_axis(pspecs, "data")
            if cfg.param_mode == "zero1" else None
        )
        step = make_train_step(model, ocfg, compute_pspecs=compute_pspecs)
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        oshard = {
            "m": pshard,
            "v": pshard,
            "step": NamedSharding(mesh, P()),
        }
        return CellSpec(
            fn=step,
            args=(params_shape, opt_shape, batch),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        step = make_prefill_step(model, shape.seq_len)
        return CellSpec(
            fn=step,
            args=(params_shape, batch),
            in_shardings=(pshard, bshard),
            out_shardings=None,
            donate_argnums=(),
        )

    # decode
    step = make_decode_step(model)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )
    cshard = cache_shardings(cache_shape, mesh, shape.global_batch)
    token = sds((shape.global_batch, 1), jnp.int32)
    pos = sds((), jnp.int32)
    bspec = NamedSharding(
        mesh, shard_rules.batch_pspec(mesh, shape.global_batch, extra_dims=1)
    )
    return CellSpec(
        fn=step,
        args=(params_shape, cache_shape, token, pos),
        in_shardings=(pshard, cshard, bspec, NamedSharding(mesh, P())),
        out_shardings=(None, cshard),
        donate_argnums=(1,),
    )


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Spec'd skip rules (DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: 524k dense-KV decode is O(S^2); skipped per assignment"
    return True, ""
