"""Sharding rules: param-tree paths -> PartitionSpecs (GSPMD/pjit).

Strategy (MaxText-style 2D "FSDP + TP"):
  * weight matrices: d_model-ish dim sharded over ``data`` (FSDP — GSPMD
    inserts per-layer all-gathers under the scan), wide dim (d_ff, heads,
    vocab, ssm inner) sharded over ``model`` (tensor parallelism);
  * embeddings: vocab over ``model``;
  * MoE expert stacks: (E, D, F) -> (None, data, model) — weights stay put,
    tokens stay put, contractions reduce over sharded dims;
  * vectors (norm scales, biases, A_log...) replicated unless they span a
    model-sharded dim (qkv biases);
  * the multi-pod ``pod`` axis shards only the batch — gradient reduction
    over pods is then a separate, DCN-crossing all-reduce stage, which is
    the hierarchy a real 2-pod job wants.

Uneven shards (12 heads on 16-way model axis, 51866-vocab, 40 experts) are
legal — GSPMD pads — and the waste shows up honestly in the roofline's
MODEL_FLOPS / HLO_FLOPs ratio.

Activation/batch specs live in `batch_pspec` / `cache_pspec`: batch dims
shard over (pod, data) when divisible; KV-cache sequence dim shards over
``model`` (flash-decode style distributed KV).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ----------------------------------------------------- coadd mesh residency ---


def shard_count(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    """Total number of shards over the given mesh axes."""
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def image_axis_sharding(mesh: Mesh, shard_axes: Tuple[str, ...]) -> NamedSharding:
    """NamedSharding splitting an image-major (M, ...) array over `shard_axes`.

    Used by `PackedDataset.to_mesh` to pin a whole coadd layout onto the mesh
    once: axis 0 (the flattened image axis) is split over every shard axis,
    trailing (H, W, meta...) dims are replicated within a shard.
    """
    return NamedSharding(mesh, P(tuple(shard_axes)))


def shard_local_compaction(
    union_gate: np.ndarray, n_shards: int
) -> Tuple[np.ndarray, np.ndarray, int, np.ndarray]:
    """Per-shard gather indices for a job's union flat gate (DESIGN.md §5).

    ``union_gate`` is the (M,) OR of every query's flat slot gate; a
    NamedSharding over axis 0 gives shard ``s`` the contiguous slab
    ``[s*L, (s+1)*L)`` with ``L = M // n_shards``.  Each shard should map
    only the slab entries some query selected, so this derives, per shard,
    the *local* indices of its gated slots.  The index array is padded to
    one shared static shape (`plan.scan_budget` bucket of the worst shard's
    count — shard_map compiles one program), but each shard also gets its
    OWN bucketed budget: the executor picks one power-of-two tile size
    dividing the shared budget and runs ``ceil(own_budget / tile)`` tiles
    per shard (slack rows past a shard's budget are 0-padded, gate-False
    entries), so quiet shards stop paying the busiest shard's gather+map
    cost (the ROADMAP two-tier budget).

    Returns ``(local_idx (S, G) int32, pad_mask (S, G) bool, G,
    budgets (S,) int32)`` with ``G == budgets.max()``; padding entries
    point at local slot 0 and are masked False in the compacted per-query
    gates, the same duplicate-then-mask discipline as `plan.compact_gate`.
    """
    from repro.core.plan import scan_budget

    m = union_gate.shape[0]
    if m % n_shards:
        raise ValueError(
            f"shard count {n_shards} must divide flat length {m}"
        )
    local_len = m // n_shards
    per_shard = union_gate.reshape(n_shards, local_len)
    counts = per_shard.sum(axis=1)
    budgets = np.array(
        [scan_budget(int(c), local_len) for c in counts], np.int32
    )
    budget = int(budgets.max())
    local_idx = np.zeros((n_shards, budget), np.int32)
    pad_mask = np.zeros((n_shards, budget), bool)
    for s in range(n_shards):
        nz = np.nonzero(per_shard[s])[0][:budget]
        local_idx[s, : len(nz)] = nz
        pad_mask[s, : len(nz)] = True
    return local_idx, pad_mask, budget, budgets


# ------------------------------------------------------------- shard_map ---


def shard_map_compat(f, mesh=None, in_specs=None, out_specs=None, check=True):
    """`shard_map` across the jax API break.

    jax >= 0.6 exposes top-level ``jax.shard_map`` (mesh optional, VMA check
    named ``check_vma``); jax 0.4.x only has the experimental entry point
    (mesh required, check named ``check_rep``).  ``mesh=None`` under the old
    API resolves the active ``with mesh:`` context.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(in_specs=in_specs, out_specs=out_specs, check_vma=check)
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is None:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError(
                "shard_map_compat(mesh=None) needs an active Mesh context "
                "under jax<0.6"
            )
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


# ---------------------------------------------------------------- params ---

# name -> spec template for the *trailing* dims; leading (stacked-layer /
# group) dims get None.
_MATRIX_RULES = {
    # input embedding: shard d_model — vocab-sharding the gather costs an
    # f32 (B,S,D) all-reduce every step (§Perf B2).  Tied tables (gemma,
    # qwen2-1.5b, mamba2) keep vocab-sharding via the "embedding_tied" rule
    # so the unembed contraction stays collective-free.
    "embedding": (None, "model"),
    "embedding_tied": ("model", None),
    "unembed": ("model", None),
    "w_q": ("data", "model"),
    "w_k": ("data", "model"),
    "w_v": ("data", "model"),
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_o": ("model", "data"),
    "w_down": ("model", "data"),
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "router": (None, None),
    "conv_w": (None, "model"),
}
# 3D expert stacks (E, ., .): replicate over `data` — expert weights are
# small relative to the token buffers they contract with, and data-sharding
# their contraction dim makes GSPMD all-reduce the (much larger) activations
# (§Perf A3: 8 GB/layer for granite).  TP over d_ff only.
_EXPERT_RULES = {
    "w_gate": (None, None, "model"),
    "w_up": (None, None, "model"),
    "w_down": (None, "model", None),
}
_VECTOR_RULES = {
    "b_q": ("model",),
    "b_k": ("model",),
    "b_v": ("model",),
    "conv_b": ("model",),
}


def _fit_to_shape(spec_axes, shape, mesh: Mesh) -> P:
    """Drop axis assignments whose dim isn't divisible by the mesh axis.

    Explicit NamedShardings on jit arguments require exact divisibility
    (unlike internal GSPMD propagation) — non-divisible dims (12 q-heads on a
    16-way model axis, vocab 51866, 40 experts...) are replicated instead,
    and the lost parallelism shows up honestly in the roofline.
    """
    fitted = []
    for dim, ax in zip(shape, spec_axes):
        if ax is None:
            fitted.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        fitted.append(ax if dim % size == 0 else None)
    return P(*fitted)


def _spec_for(path: Tuple, leaf, mesh: Mesh) -> P:
    name = None
    for part in reversed(path):
        key = getattr(part, "key", None)
        if isinstance(key, str) and key not in ("moe", "mamba", "attn", "cross", "mlp"):
            name = key
            break
    shape = tuple(leaf.shape)
    ndim = len(shape)
    in_moe = any(getattr(p, "key", None) == "moe" for p in path)

    if name in _MATRIX_RULES:
        if in_moe and name in _EXPERT_RULES:
            base = _EXPERT_RULES[name]
        else:
            base = _MATRIX_RULES[name]
        pad = ndim - len(base)
        if pad < 0:  # smaller than template (shouldn't happen)
            return P()
        return _fit_to_shape([None] * pad + list(base), shape, mesh)
    if name in _VECTOR_RULES:
        base = _VECTOR_RULES[name]
        pad = ndim - len(base)
        return _fit_to_shape([None] * pad + list(base), shape, mesh)
    # scales, A_log, D, dt_bias, biases without rules: replicate.
    return P(*([None] * ndim))


def _substitute_pure_dp(base):
    """pure_dp: model axis becomes extra FSDP — "data"->("data","model"),
    "model"->None (no tensor parallelism)."""
    out = []
    for ax in base:
        if ax == "data":
            out.append(("data", "model"))
        elif ax == "model":
            out.append(None)
        else:
            out.append(ax)
    return out


def param_pspecs(params_shape, mesh: Mesh, pure_dp: bool = False) -> Any:
    """Tree of PartitionSpecs matching a params (or opt-state) shape tree."""
    tied = (
        isinstance(params_shape, dict)
        and "embed" in params_shape
        and "unembed" not in params_shape.get("embed", {})
    )

    def spec(p, l):
        name = getattr(p[-1], "key", None)
        if name == "embedding" and tied:
            s = _fit_to_shape(list(_MATRIX_RULES["embedding_tied"]), tuple(l.shape), mesh)
        else:
            s = _spec_for(p, l, mesh)
        if pure_dp:
            s = _fit_to_shape(_substitute_pure_dp(list(s)), tuple(l.shape), mesh)
        return s

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def strip_axis(tree_specs, axis: str = "data"):
    """Remove one mesh axis from every spec (zero1: compute params keep only
    model-axis TP; the data axis holds sharded fp32 masters + moments)."""

    def strip(spec):
        out = []
        for entry in spec:
            if entry == axis:
                out.append(None)
            elif isinstance(entry, tuple):
                kept = tuple(a for a in entry if a != axis)
                out.append(kept if kept else None)
            else:
                out.append(entry)
        return P(*out)

    return jax.tree.map(strip, tree_specs, is_leaf=lambda x: isinstance(x, P))


def named_shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ----------------------------------------------------------- activations ---
def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _dp_if_divisible(mesh: Mesh, size: int, pure_dp: bool = False):
    axes = dp_axes(mesh)
    if pure_dp and "model" in mesh.shape:
        axes = axes + ("model",)
    while axes:
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if size % total == 0:
            return axes
        axes = axes[1:] if len(axes) > 1 else ()
    if "data" in mesh.shape and size % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_pspec(mesh: Mesh, global_batch: int, extra_dims: int = 1,
                pure_dp: bool = False) -> P:
    """(B, S[, ...]): batch over (pod, data) when divisible, rest replicated."""
    b_axes = _dp_if_divisible(mesh, global_batch, pure_dp)
    return P(b_axes, *([None] * extra_dims))


def cache_pspec(mesh: Mesh, batch: int, leaf_shape, seq_axis: int) -> P:
    """Stacked KV cache (L, B, T, H, Dh): B over data axes, T over model."""
    b_axes = _dp_if_divisible(mesh, batch)
    spec = [None] * len(leaf_shape)
    spec[1] = b_axes
    if "model" in mesh.shape and leaf_shape[seq_axis] % mesh.shape["model"] == 0:
        spec[seq_axis] = "model"
    return P(*spec)
