"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Per (arch x shape) cell, three terms in seconds-per-step (per device — the
SPMD program is per-device, so per-device seconds == global seconds):

  compute    = HLO_FLOPs_dev / PEAK_FLOPS
  memory     = HLO_bytes_dev_adjusted / HBM_BW
  collective = link_traffic_dev / ICI_BW

HLO_FLOPs/bytes come from the dry-run's unrolled reduced-depth probes scaled
to full depth (`repro.launch.dryrun`), because HloCostAnalysis counts
while-loop bodies once.

Memory adjustment (documented, exact given shapes): the probes use *dense*
attention for exact FLOPs, which materializes S x S score tensors that a
fused flash kernel keeps in VMEM.  We subtract the analytic score-tensor
traffic (4 passes x fp32) and add the flash-streaming extra (K/V re-read
once per q-block pass).  Raw and adjusted bytes are both reported.

MODEL_FLOPS = 6*N*D (dense; N=params, D=tokens) or 6*N_active*D (MoE) for
train; 2*N_active per generated token for decode; 2*N_active*D for prefill.
The ratio MODEL_FLOPS / HLO_FLOPs_global measures how much compiled compute
is "useful" (remat, padding-replication and attention waste show up here).
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs.base import SHAPE_BY_NAME
from repro.configs.registry import get_config

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (per-device link budget)
N_CHIPS_SINGLE = 256


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    memory_raw_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    step_s: float            # max of the three terms (no-overlap bound)
    peak_fraction: float     # model_flops / (step_s * chips * PEAK)
    note: str = ""


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch


def _attention_bytes_adjustment(arch: str, shape_name: str) -> float:
    """Score-tensor HBM traffic the dense-attention probes add vs flash.

    4 passes (write scores, read+write softmax, read for PV) x fp32 over
    (B, Hq, S_q, S_k) per attention instance, per device, fwd; x3 with
    backward for train.  Exact given config shapes; returns bytes to
    subtract from the probe's per-device 'bytes accessed'.
    """
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    if shape.kind == "decode":
        return 0.0  # decode probes never materialize S x S
    s = shape.seq_len
    b_dev = max(shape.global_batch // 16, 1)  # data axis = 16 on single pod
    # 2 effective HBM passes over the score tensor forward (QK^T output +
    # softmax read/write fuse on CPU-XLA into ~2 round trips), x3 with the
    # rematted backward.
    passes = 2.0 * (3.0 if shape.kind == "train" else 1.0)

    def attn_traffic(n_inst: int, s_q: int, s_k: int, heads: int) -> float:
        return passes * 4.0 * b_dev * heads * s_q * s_k * n_inst

    fam = cfg.family
    h = cfg.n_heads
    if fam in ("dense", "moe"):
        return attn_traffic(cfg.n_layers, s, s, h)
    if fam == "vlm":
        cross = attn_traffic(cfg.n_layers // cfg.cross_attn_period, s,
                             cfg.n_image_tokens, h)
        return attn_traffic(cfg.n_layers, s, s, h) + cross
    if fam == "encdec":
        enc = attn_traffic(cfg.n_encoder_layers, cfg.encoder_seq, cfg.encoder_seq, h)
        dec = attn_traffic(cfg.n_layers, s, s, h)
        cross = attn_traffic(cfg.n_layers, s, cfg.encoder_seq, h)
        return enc + dec + cross
    if fam == "hybrid":
        groups = cfg.n_layers // cfg.shared_attn_period
        return attn_traffic(groups, s, s, h)
    return 0.0  # ssm: no attention


def load_cell(dryrun_dir: str, arch: str, shape: str) -> Optional[Dict]:
    path = os.path.join(dryrun_dir, f"{arch}__{shape}__single.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def analyze_cell(data: Dict) -> Optional[CellRoofline]:
    arch, shape = data["arch"], data["shape"]
    if "skipped" in data:
        return CellRoofline(
            arch, shape, 0, 0, 0, 0, "skipped", 0, 0, 0, 0, 0,
            note=data["skipped"],
        )
    if "scaled_cost" not in data:
        return None
    sc = data["scaled_cost"]
    flops_dev = sc["flops"]
    bytes_dev_raw = sc["bytes"]
    coll_dev = sc["coll"]

    adj = _attention_bytes_adjustment(arch, shape)
    # Analytic floor: sharded params streamed once per use (+grad/opt traffic
    # for train) plus one activation round-trip per layer — the memory term
    # can never fall below genuine weight/activation streaming.
    cfg = get_config(arch)
    shp = SHAPE_BY_NAME[shape]
    params_dev = cfg.param_count() * 4.0 / N_CHIPS_SINGLE
    uses = 3.0 if shp.kind == "train" else 1.0      # fwd + bwd(remat) reads
    opt_traffic = 3.0 * params_dev * (2.0 if shp.kind == "train" else 0.0)
    tokens_dev = shp.global_batch * (shp.seq_len if shp.kind != "decode" else 1) / 16.0
    act_traffic = 2.0 * tokens_dev * cfg.d_model * 2.0 * max(cfg.n_layers, 1) * uses
    floor = uses * params_dev + opt_traffic + act_traffic
    bytes_dev = max(bytes_dev_raw - adj, floor)
    clamped = bytes_dev_raw - adj < floor

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    memory_raw_s = bytes_dev_raw / HBM_BW
    collective_s = coll_dev / ICI_BW

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())

    mf = model_flops(arch, shape)
    hlo_global = flops_dev * N_CHIPS_SINGLE
    useful = mf / hlo_global if hlo_global else 0.0
    peak_frac = mf / (step_s * N_CHIPS_SINGLE * PEAK_FLOPS) if step_s else 0.0

    return CellRoofline(
        arch=arch, shape=shape,
        compute_s=compute_s, memory_s=memory_s, memory_raw_s=memory_raw_s,
        collective_s=collective_s, dominant=dominant,
        model_flops=mf, hlo_flops_global=hlo_global, useful_ratio=useful,
        step_s=step_s, peak_fraction=peak_frac,
        note="memory=analytic-floor" if clamped else "",
    )


def analyze_all(dryrun_dir: str = "experiments/dryrun") -> List[CellRoofline]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*__single.json"))):
        with open(path) as f:
            data = json.load(f)
        cell = analyze_cell(data)
        if cell:
            out.append(cell)
    return out


def format_table(cells: List[CellRoofline]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
        f"{'collect':>9s} {'dominant':>10s} {'useful':>7s} {'peak%':>6s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if c.dominant == "skipped":
            lines.append(f"{c.arch:22s} {c.shape:12s} {'—':>9s} {'—':>9s} {'—':>9s} "
                         f"{'skip':>10s} {'—':>7s} {'—':>6s}")
            continue
        lines.append(
            f"{c.arch:22s} {c.shape:12s} {c.compute_s:9.4f} {c.memory_s:9.4f} "
            f"{c.collective_s:9.4f} {c.dominant:>10s} {c.useful_ratio:7.3f} "
            f"{100*c.peak_fraction:6.2f}"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--json-out")
    args = ap.parse_args()
    cells = analyze_all(args.dryrun_dir)
    print(format_table(cells))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([dataclasses.asdict(c) for c in cells], f, indent=1)


if __name__ == "__main__":
    main()
