"""HLO text analysis: collective traffic extraction.

``compiled.as_text()`` (post-SPMD-partitioning) is a per-device program;
collective operand/result shapes are per-device.  We extract every
collective op, its payload bytes and its replica-group size, and convert to
*per-device link traffic* with the standard ring-algorithm factors:

  all-reduce          2 * D * (n-1)/n
  all-gather          D_out * (n-1)/n
  reduce-scatter      D_in  * (n-1)/n  (= D_out * (n-1))
  all-to-all          D * (n-1)/n
  collective-permute  D

The collective roofline term is  sum(traffic) / link_bw  — equivalent to the
spec's  collective_bytes / (chips * link_bw)  with collective_bytes summed
over chips.

CAVEAT (documented in EXPERIMENTS.md): while-loop bodies appear once in the
text, so callers must scale loop-resident collectives by trip count — the
dry-run handles this by probing unrolled reduced-depth model variants and
scaling analytically (see `repro.launch.dryrun`).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


def link_traffic(op: str, payload: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * payload * (n - 1) / n
    if op == "all-gather":
        return payload * (n - 1) / n
    if op == "reduce-scatter":
        return payload * (n - 1)  # payload here is the (scattered) output
    if op == "all-to-all":
        return payload * (n - 1) / n
    if op == "collective-permute":
        return float(payload)
    return float(payload)


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, payload_bytes, link_bytes} for one HLO module."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "payload_bytes": 0.0, "link_bytes": 0.0}
    )
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, op, is_start = m.group(1), m.group(2), m.group(3)
        if is_start:
            # async start: result is (operand, result[, scratch]) — halve to
            # avoid double counting operand+result.
            payload = _shape_bytes(shape_txt) // 2
        else:
            payload = _shape_bytes(shape_txt)
        n = _group_size(line)
        s = stats[op]
        s["count"] += 1
        s["payload_bytes"] += payload
        s["link_bytes"] += link_traffic(op, payload, n)
    return dict(stats)


def total_link_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(s["link_bytes"] for s in stats.values())
