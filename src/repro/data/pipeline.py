"""Deterministic-resume sharded data pipeline.

Fault-tolerance contract (the training-loop half of the paper's re-execution
story): a batch is a **pure function of (seed, step)** — no iterator state —
so a job restarted from a step-N checkpoint consumes exactly the batches it
would have seen without the failure.  Elastic scaling follows for free: the
global batch is assembled identically regardless of worker count, and each
worker slices its shard by mesh position.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.data.packing import TokenShards


@dataclasses.dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    seed: int = 0


class TokenPipeline:
    """Samples fixed (B, S+1) windows from packed shards, step-indexed."""

    def __init__(self, shards: TokenShards, cfg: PipelineConfig):
        if shards.n_shards == 0:
            raise ValueError("empty shard set")
        self.shards = shards
        self.cfg = cfg
        self._flat = shards.tokens.reshape(-1)
        self._limit = len(self._flat) - (cfg.seq_len + 1)
        if self._limit <= 0:
            raise ValueError("corpus smaller than one sequence")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch for ``step`` (deterministic, restart-safe)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, int(step)])
        )
        starts = rng.integers(0, self._limit, size=cfg.global_batch)
        idx = starts[:, None] + np.arange(cfg.seq_len + 1)[None, :]
        window = self._flat[idx]
        return {
            "tokens": window[:, :-1].astype(np.int32),
            "labels": window[:, 1:].astype(np.int32),
        }

    def host_slice(
        self, batch: Dict[str, np.ndarray], host_id: int, n_hosts: int
    ) -> Dict[str, np.ndarray]:
        """Per-host slice of the global batch (multi-host loading)."""
        b = self.cfg.global_batch
        per = b // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in batch.items()}
