"""Token-stream packing: the paper's sequence-file idea applied to LM data.

The coaddition pipeline went fast when many small files became few large,
indexed, *structured* containers (paper §4.1.2-4.1.3).  The training data
pipeline applies the same recipe to documents:

  * documents (variable-length "small files") are packed back-to-back into
    fixed-length **token shards** (large containers; static shapes for TPU);
  * shards are *structured* by source/domain key so a run can prune shards
    by metadata before dispatch (the glob prefilter analogue — e.g. train on
    a domain subset without touching the rest of the corpus);
  * a shard index maps document id -> (shard, offset) (the SQL analogue).

Packing emits boundary-crossing documents contiguously (GPT-style) with
document ids carried alongside for masking experiments.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class TokenShards:
    tokens: np.ndarray        # (n_shards, shard_len) int32
    doc_ids: np.ndarray       # (n_shards, shard_len) int32
    source_key: np.ndarray    # (n_shards,) int32 — structured container key
    index: Dict[int, Tuple[int, int]]  # doc -> (shard, offset)

    @property
    def n_shards(self) -> int:
        return self.tokens.shape[0]

    def prune(self, keys: Sequence[int]) -> "TokenShards":
        """Structured-container pruning: keep only shards from given sources."""
        mask = np.isin(self.source_key, np.asarray(list(keys)))
        sel = np.nonzero(mask)[0]
        remap = {int(s): i for i, s in enumerate(sel)}
        index = {
            d: (remap[p], o) for d, (p, o) in self.index.items() if p in remap
        }
        return TokenShards(
            self.tokens[sel], self.doc_ids[sel], self.source_key[sel], index
        )


def pack_documents(
    docs: List[np.ndarray],
    doc_sources: Optional[Sequence[int]],
    shard_len: int,
    structured: bool = True,
) -> TokenShards:
    """Pack variable-length docs into fixed shards, grouped by source."""
    n = len(docs)
    sources = list(doc_sources) if doc_sources is not None else [0] * n
    order = sorted(range(n), key=lambda i: sources[i]) if structured else list(range(n))

    shards: List[np.ndarray] = []
    dids: List[np.ndarray] = []
    skeys: List[int] = []
    index: Dict[int, Tuple[int, int]] = {}

    cur = np.zeros((shard_len,), np.int32)
    cur_did = np.full((shard_len,), -1, np.int32)
    fill = 0
    cur_key = sources[order[0]] if order else 0

    def flush():
        nonlocal cur, cur_did, fill
        if fill == 0:
            return
        shards.append(cur.copy())
        dids.append(cur_did.copy())
        skeys.append(cur_key)
        cur = np.zeros((shard_len,), np.int32)
        cur_did = np.full((shard_len,), -1, np.int32)
        fill = 0

    for i in order:
        if structured and sources[i] != cur_key:
            flush()
            cur_key = sources[i]
        doc = np.asarray(docs[i], np.int32)
        pos = 0
        index[i] = (len(shards), fill)
        while pos < len(doc):
            take = min(shard_len - fill, len(doc) - pos)
            cur[fill : fill + take] = doc[pos : pos + take]
            cur_did[fill : fill + take] = i
            fill += take
            pos += take
            if fill == shard_len:
                flush()
    flush()
    return TokenShards(
        np.stack(shards) if shards else np.zeros((0, shard_len), np.int32),
        np.stack(dids) if dids else np.zeros((0, shard_len), np.int32),
        np.asarray(skeys, np.int32),
        index,
    )


def synthetic_corpus(
    n_docs: int = 512,
    vocab: int = 1024,
    mean_len: int = 384,
    n_sources: int = 4,
    seed: int = 0,
) -> Tuple[List[np.ndarray], List[int]]:
    """Zipf-ish seeded corpus for tests/examples (per-source token bias)."""
    rng = np.random.default_rng(seed)
    docs = []
    srcs = []
    for i in range(n_docs):
        src = int(rng.integers(n_sources))
        ln = max(8, int(rng.poisson(mean_len)))
        base = rng.zipf(1.4, size=ln) % (vocab // 2)
        toks = (base + src * (vocab // 2) // n_sources) % vocab
        docs.append(toks.astype(np.int32))
        srcs.append(src)
    return docs, srcs
