"""int8 gradient compression with error feedback (1-bit-Adam-style family).

For cross-pod (DCN) gradient reduction the wire format matters more than
FLOPs: int8 quantization cuts the all-reduce payload 4x vs fp32.  Plain
quantization biases updates; **error feedback** (Seide et al. 2014; Karimireddy
et al. 2019) carries the quantization residual into the next step, restoring
convergence to the exact trajectory asymptotically.

Usage in the train step (multi-pod): compress -> all-reduce int8/psum over
``pod`` -> decompress; intra-pod reduction stays full-precision on ICI.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_leaf(g: jnp.ndarray, err: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale f32 scalar, new_err)."""
    combined = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(combined)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(combined / scale), -127, 127).astype(jnp.int8)
    new_err = combined - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_leaf(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, err_state):
    """Tree-wise compression. Returns (q_tree, scale_tree, new_err_state)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    qs, ss, es = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress_leaf(g, e)
        qs.append(q)
        ss.append(s)
        es.append(ne)
    unf = treedef.unflatten
    return unf(qs), unf(ss), unf(es)


def decompress_tree(q_tree, scale_tree):
    return jax.tree.map(decompress_leaf, q_tree, scale_tree)


def compressed_gradients(grads, err_state):
    """compress -> (simulated wire) -> decompress, threading error feedback."""
    q, s, new_err = compress_tree(grads, err_state)
    return decompress_tree(q, s), new_err
