"""AdamW in pure JAX: global-norm clip, decoupled weight decay, ZeRO-1-free.

Optimizer state is a pytree shaped like params, so it inherits the params'
2D (data x model) sharding — i.e. the m/v moments are already fully
sharded across the pod (the ZeRO-1 property falls out of the FSDP layout
rather than needing a separate partitioner).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Callable[[jnp.ndarray], jnp.ndarray] | None = None


def adamw_init(params) -> Dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)  # noqa: E731
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    grads, state: Dict, params, cfg: AdamWConfig
) -> Tuple[Any, Dict, Dict]:
    step = state["step"] + 1
    lr = cfg.lr if cfg.schedule is None else cfg.schedule(step) * cfg.lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["v"], grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return (
            p.astype(jnp.float32)
            - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32))
        ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
