"""LR schedules (multiplier form: schedule(step) in [0, 1])."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup_steps: int, total_steps: int, min_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
