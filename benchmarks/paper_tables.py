"""Reproduction benchmarks: one function per paper table/figure.

The absolute times are CPU-container times on a miniature synthetic
Stripe 82; what reproduces is the paper's *structure*: which method beats
which, and why (job-init dispatch cost vs mapper waste vs locality).
Paper reference points (400-node CluE cluster, 100k files):
  Table 1:  raw+prefilter 42.0 / 25.9 min; unstructured seq 9.2 / 4.2;
            structured seq+prefilter 4.0 / 2.7; SQL->unstructured 7.8 / 3.5;
            SQL->structured 4.1 / 2.2   (1-deg / quarter-deg queries)
  Table 2:  mapper input records 100058 / 13415 / 13335 / 3885 / 465.
  Fig. 8:   job time dominated by Construct File Splits (per-file RPCs).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import CoaddEngine, CoaddQuery, METHODS, SurveyConfig, make_survey

BENCH_SURVEY = SurveyConfig(
    n_runs=6, n_camcols=6, n_bands=5, n_fields=10,
    height=24, width=24, n_sources=250, seed=82,
)

# The paper's two query sizes: ~1 deg and ~1/4 deg square.
QUERY_LARGE = CoaddQuery(band="r", ra_bounds=(37.6, 38.6), dec_bounds=(-0.55, 0.45), npix=128)
QUERY_SMALL = CoaddQuery(band="r", ra_bounds=(38.0, 38.25), dec_bounds=(-0.2, 0.05), npix=128)

_ENGINE_CACHE: Dict[bool, CoaddEngine] = {}
_SURVEY_CACHE: Dict[int, object] = {}


def get_survey():
    if 0 not in _SURVEY_CACHE:
        _SURVEY_CACHE[0] = make_survey(BENCH_SURVEY)
    return _SURVEY_CACHE[0]


def get_engine(sparse: bool = True) -> CoaddEngine:
    """Benchmark engines share one survey; sparse=False is the dense-scan
    baseline the sparse-execution rows are compared against."""
    if sparse not in _ENGINE_CACHE:
        _ENGINE_CACHE[sparse] = CoaddEngine(
            get_survey(), pack_capacity=64, sparse=sparse
        )
    return _ENGINE_CACHE[sparse]


def bench_table1(repeats: int = 3) -> List[str]:
    """Coadd running times for two query sizes x six methods (Table 1)."""
    eng = get_engine()
    rows = []
    ref = {}
    for q, qname in ((QUERY_LARGE, "1deg"), (QUERY_SMALL, "qdeg")):
        # warmup compiles once per (shape) so timings measure the pipeline,
        # not XLA compilation (the paper's cluster reuses JVMs similarly).
        for m in METHODS:
            eng.run(q, m)
        for m in METHODS:
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                r = eng.run(q, m)
                ts.append(time.perf_counter() - t0)
            best = min(ts)
            ref[(qname, m)] = best
            rows.append(f"table1/{qname}/{m},{best*1e6:.0f},s={best:.3f}")
    # Derived: the paper's headline speedups (vs prefiltered raw FITS).
    for qname in ("1deg", "qdeg"):
        base = ref[(qname, "raw_fits_prefiltered")]
        for m in ("unstructured_seq", "structured_seq_prefiltered",
                  "sql_unstructured", "sql_structured"):
            rows.append(
                f"table1/{qname}/speedup_{m},{base/ref[(qname,m)]:.2f},x_vs_prefiltered_raw"
            )
    return rows


def bench_table2() -> List[str]:
    """Mapper input records per method (Table 2)."""
    eng = get_engine()
    rows = []
    for q, qname in ((QUERY_LARGE, "1deg"), (QUERY_SMALL, "qdeg")):
        for m in METHODS:
            r = eng.run(q, m)
            rows.append(
                f"table2/{qname}/{m},{r.stats.files_considered},"
                f"contributing={r.stats.files_contributing};packs={r.stats.packs_touched}"
            )
    return rows


def bench_fig8_breakdown() -> List[str]:
    """Stage breakdown: job-init (locate/dispatch) vs map+reduce (Fig. 8)."""
    eng = get_engine()
    rows = []
    for m in ("raw_fits_prefiltered", "structured_seq_prefiltered", "sql_structured"):
        eng.run(QUERY_LARGE, m)  # warm
        r = eng.run(QUERY_LARGE, m)
        s = r.stats
        rows.append(f"fig8/{m}/locate,{s.t_locate_s*1e6:.0f},job_init")
        rows.append(f"fig8/{m}/map_reduce,{s.t_map_reduce_s*1e6:.0f},compute")
        rows.append(
            f"fig8/{m}/init_fraction,{100*s.t_locate_s/max(s.t_total_s,1e-9):.1f},pct_of_total"
        )
    return rows


def bench_consistency() -> List[str]:
    """All methods produce the same coadd (correctness gate for the above)."""
    eng = get_engine()
    base = eng.run(QUERY_SMALL, "sql_structured")
    rows = []
    for m in METHODS:
        r = eng.run(QUERY_SMALL, m)
        err = float(np.abs(r.coadd - base.coadd).max())
        rows.append(f"consistency/{m},{err:.2e},max_abs_diff_vs_sql_structured")
        assert err < 1e-2, (m, err)
    return rows
