"""CI perf gate over BENCH_coadd.json (ROADMAP bench-tracking item).

Compares the current --quick run against the base branch's BENCH_coadd
artifact and fails when any us/image row (per-method or batched) regresses
by more than ``--threshold`` (default 1.5x — wide enough for shared-runner
CPU jitter, tight enough to catch real dispatch/scan regressions).  Also
appends one trajectory row per run to ``BENCH_trajectory.jsonl`` so the
us/image history across PRs is a downloadable artifact rather than
archaeology over old CI logs.

With no baseline (first run on a branch, expired artifacts) the current
report is its own baseline: the gate degrades to a self-consistency pass
and says so, rather than failing closed on missing history.

Independently of any baseline, the fault-tracker clean-path overhead row
(``fault_overhead`` in the report) is gated absolutely at
``--fault-threshold`` (default 1.1x): the WindowTracker must not cost more
than 10% over the untracked streaming loop, and its result must be bitwise
identical.  The disk-journal row (``durable_overhead``) is gated the same
way at ``--durable-threshold`` (default 1.15x): writing every window
partial through a checksummed, fsynced journal must stay within 15% of the
in-memory run, bitwise-equal, with zero journal jobs left after a clean
exit.  Likewise the brick rows (``bricks`` in the report) are gated
absolutely at ``--brick-threshold`` (default 3.0x): warm brick-served
queries must beat the brick-free fresh scan by at least that factor, with
bitwise-identical results.  The serving rows (``serving``) are gated
absolutely at ``--serve-threshold`` (default 2.0x): at the highest
measured concurrency a cache-cold `CoaddService` must answer the client
burst at that multiple of the serial engine.run queries/sec, with zero
shed and the cache-warm replay never slower than cold.  The robust rows
(``robust_stack``/``diff_detect``) are gated absolutely at
``--robust-threshold`` (default 2.0x): each added pass may at most multiply
the previous schedule's cost by the threshold — the sigma-clipped mean (one
extra fixed-operand re-scan) vs the plain mean, the binapprox median (one
further histogram round) vs the clipped mean — and the difference-imaging
drill must keep recovering >= 95% of its injected transients with zero
spurious detections.

  python -m benchmarks.perf_gate --current BENCH_coadd.json \
      [--baseline path.json] [--history old_trajectory.jsonl] \
      [--trajectory BENCH_trajectory.jsonl] [--threshold 1.5] \
      [--sha abc123] [--ref refs/pull/7]
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import shutil
import sys
from typing import Dict, List, Optional, Tuple


def _us_per_image_rows(report: Dict) -> Dict[str, float]:
    """Every --quick us/image row, namespaced: methods/<m>, batched/b<K>."""
    rows: Dict[str, float] = {}
    for m, rec in report.get("methods", {}).items():
        if rec.get("us_per_image"):
            rows[f"methods/{m}"] = float(rec["us_per_image"])
    for bs, rec in report.get("batched", {}).items():
        if rec.get("us_per_image"):
            rows[f"batched/b{bs}"] = float(rec["us_per_image"])
    return rows


def gate(current: Dict, baseline: Dict, threshold: float) -> Tuple[List[str], List[str]]:
    """(regressions, summary_lines) for current vs baseline us/image rows."""
    cur = _us_per_image_rows(current)
    base = _us_per_image_rows(baseline)
    regressions: List[str] = []
    lines: List[str] = []
    for name in sorted(cur):
        if name not in base or base[name] <= 0:
            lines.append(f"  {name}: {cur[name]:.1f} us/img (new row)")
            continue
        ratio = cur[name] / base[name]
        mark = ""
        if ratio > threshold:
            mark = f"  << REGRESSION (>{threshold:.2f}x)"
            regressions.append(
                f"{name}: {base[name]:.1f} -> {cur[name]:.1f} us/img "
                f"({ratio:.2f}x)"
            )
        lines.append(
            f"  {name}: {base[name]:.1f} -> {cur[name]:.1f} us/img "
            f"({ratio:.2f}x){mark}"
        )
    return regressions, lines


def fault_overhead_gate(current: Dict, threshold: float) -> Tuple[List[str], List[str]]:
    """Self-contained gate on the fault tracker's clean-path cost (§8).

    Unlike the us/image rows this needs no baseline artifact: the tracker-on
    and tracker-off engines ran side by side in the same --quick invocation,
    so the ratio (and the bitwise agreement of their results) is gated
    absolutely, at <= ``threshold``.
    """
    rec = current.get("fault_overhead")
    if not rec:
        return [], ["  fault_overhead: no rows (old artifact?)"]
    ratio = float(rec["overhead_ratio"])
    regressions: List[str] = []
    lines = [
        f"  fault_overhead: tracker on {rec['us_per_image_tracker_on']:.1f} "
        f"vs off {rec['us_per_image_tracker_off']:.1f} us/img "
        f"({ratio:.3f}x, gate <= {threshold:.2f}x)"
    ]
    if ratio > threshold:
        regressions.append(
            f"fault_overhead: {ratio:.3f}x > {threshold:.2f}x clean-path budget"
        )
    if not rec.get("bitwise_equal", True):
        regressions.append(
            "fault_overhead: tracker-on result differs from tracker-off "
            "(scheduling must never change arithmetic)"
        )
    return regressions, lines


def durable_overhead_gate(
    current: Dict, threshold: float
) -> Tuple[List[str], List[str]]:
    """Absolute gate on the disk journal's clean-path cost (§8 durable).

    Journal-on and journal-off engines ran side by side in the same --quick
    invocation, so no baseline artifact is needed: the ratio is gated
    absolutely at <= ``threshold``, the results must agree bitwise, and a
    clean run must leave zero journal jobs behind (completion GC).
    """
    rec = current.get("durable_overhead")
    if not rec:
        return [], ["  durable_overhead: no rows (old artifact?)"]
    ratio = float(rec["overhead_ratio"])
    regressions: List[str] = []
    lines = [
        f"  durable_overhead: journal on {rec['us_per_image_journal_on']:.1f} "
        f"vs off {rec['us_per_image_journal_off']:.1f} us/img "
        f"({ratio:.3f}x, gate <= {threshold:.2f}x)"
    ]
    if ratio > threshold:
        regressions.append(
            f"durable_overhead: {ratio:.3f}x > {threshold:.2f}x "
            f"clean-path budget"
        )
    if not rec.get("bitwise_equal", True):
        regressions.append(
            "durable_overhead: journaled result differs from in-memory "
            "(the journal is a side channel, never an operand)"
        )
    if rec.get("jobs_left", 0):
        regressions.append(
            f"durable_overhead: {rec['jobs_left']} journal job(s) survived "
            f"a clean run (completion GC broken)"
        )
    return regressions, lines


def brick_gate(current: Dict, threshold: float) -> Tuple[List[str], List[str]]:
    """Absolute gate on brick-served query speedup (DESIGN.md §9).

    Warm brick mosaics and fresh lattice-window scans ran side by side in
    the same --quick invocation, so no baseline artifact is needed: every
    prefiltered-method row must serve cached at >= ``threshold`` x faster
    than cold, and every row (any method) must agree bitwise — the cache
    trades time for storage, never arithmetic.
    """
    rec = current.get("bricks")
    if not rec or not rec.get("rows"):
        return [], ["  bricks: no rows (old artifact?)"]
    regressions: List[str] = []
    lines: List[str] = []
    for row in rec["rows"]:
        name = f"bricks/{row['method']}/k{row['k']}"
        speedup = float(row["speedup"])
        lines.append(
            f"  {name}: cached {row['us_per_query_cached']:.0f} vs cold "
            f"{row['us_per_query_cold']:.0f} us/query "
            f"({speedup:.2f}x, gate >= {threshold:.2f}x)"
        )
        if speedup < threshold:
            regressions.append(
                f"{name}: warm brick serve only {speedup:.2f}x over the "
                f"brick-free scan (< {threshold:.2f}x)"
            )
        if not row.get("bitwise_equal", True):
            regressions.append(
                f"{name}: mosaicked result differs from the fresh scan "
                "(brick serving must never change arithmetic)"
            )
    return regressions, lines


def serve_gate(current: Dict, threshold: float) -> Tuple[List[str], List[str]]:
    """Absolute gate on serving throughput under concurrency (DESIGN.md §10).

    The serial baseline and the coalesced service passes ran side by side
    in the same --quick invocation, so no baseline artifact is needed.  At
    the highest measured concurrency, a cache-cold service must answer the
    skewed client burst at >= ``threshold`` x the serial queries/sec
    (coalescing + singleflight merging is the win), with zero requests
    shed below the admission limit; the cache-warm replay must never fall
    below the cold pass.
    """
    rec = current.get("serving")
    if not rec or not rec.get("concurrency"):
        return [], ["  serving: no rows (old artifact?)"]
    regressions: List[str] = []
    lines: List[str] = []
    top = str(max(int(c) for c in rec["concurrency"]))
    for c, row in sorted(rec["concurrency"].items(), key=lambda kv: int(kv[0])):
        gated = c == top and int(c) > 1
        lines.append(
            f"  serving/c{c}: cold {row['qps_cold']:.1f} qps vs serial "
            f"{row['qps_serial']:.1f} ({row['speedup_cold']:.2f}x"
            f"{f', gate >= {threshold:.2f}x' if gated else ''}), "
            f"warm {row['qps_warm']:.1f} qps, "
            f"coalesce {row['coalesce_factor']:.1f}, shed {row['shed']}"
        )
        if gated and float(row["speedup_cold"]) < threshold:
            regressions.append(
                f"serving/c{c}: coalesced throughput only "
                f"{row['speedup_cold']:.2f}x serial (< {threshold:.2f}x)"
            )
        if row.get("shed", 0):
            regressions.append(
                f"serving/c{c}: {row['shed']} request(s) shed below the "
                f"admission limit"
            )
        if float(row["qps_warm"]) < float(row["qps_cold"]):
            regressions.append(
                f"serving/c{c}: cache-warm replay slower than cold "
                f"({row['qps_warm']:.1f} < {row['qps_cold']:.1f} qps)"
            )
    return regressions, lines


def robust_gate(current: Dict, threshold: float) -> Tuple[List[str], List[str]]:
    """Absolute gate on robust-estimator overhead (DESIGN.md §11).

    The mean, clipped, and median stacks ran interleaved in the same
    --quick invocation, so no baseline artifact is needed.  The rule is
    uniform per added pass: each extra pass may at most multiply the
    previous schedule's cost by ``threshold`` — the clipped mean (one
    extra fixed-operand re-scan over the resident warp) must stay within
    ``threshold`` x the plain mean, and the median (one further binapprox
    histogram round) within ``threshold`` x the clipped mean.  The
    diff_detect row rides along as a correctness tripwire: a detector
    that stops recovering its injections fails here, not just in the
    slow test lane.
    """
    rec = current.get("robust_stack")
    regressions: List[str] = []
    lines: List[str] = []
    if not rec:
        lines.append("  robust_stack: no rows (old artifact?)")
    else:
        r_clip = float(rec["overhead_clipped_vs_mean"])
        r_med = float(rec["overhead_median_vs_mean"])
        r_med_vs_clip = r_med / max(r_clip, 1e-9)
        lines.append(
            f"  robust_stack: clipped {rec['us_per_query_clipped']:.0f} vs "
            f"mean {rec['us_per_query_mean']:.0f} us/query "
            f"({r_clip:.2f}x, gate <= {threshold:.2f}x); "
            f"median {rec['us_per_query_median']:.0f} us/query "
            f"({r_med_vs_clip:.2f}x clipped, gate <= {threshold:.2f}x)"
        )
        if r_clip > threshold:
            regressions.append(
                f"robust_stack: clipped mean costs {r_clip:.2f}x the plain "
                f"mean (> {threshold:.2f}x per-pass budget)"
            )
        if r_med_vs_clip > threshold:
            regressions.append(
                f"robust_stack: median costs {r_med_vs_clip:.2f}x the "
                f"clipped mean (> {threshold:.2f}x per-pass budget)"
            )
    det = current.get("diff_detect")
    if not det:
        lines.append("  diff_detect: no rows (old artifact?)")
    else:
        lines.append(
            f"  diff_detect: {det['us_per_query']:.0f} us/query, recovered "
            f"{det['recovered']}/{det['n_injected']}, "
            f"spurious {det['spurious']}"
        )
        n = max(int(det["n_injected"]), 1)
        if det["recovered"] < 0.95 * n:
            regressions.append(
                f"diff_detect: recovered only {det['recovered']}/{n} "
                f"injected transients (< 95%)"
            )
        if det.get("spurious", 0):
            regressions.append(
                f"diff_detect: {det['spurious']} spurious detection(s) "
                f"(static-sky drill demands zero)"
            )
    return regressions, lines


def trajectory_row(current: Dict, sha: str, ref: str) -> Dict:
    """One compact history row: us/image per row + the streaming headline."""
    row = {
        "utc": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "sha": sha,
        "ref": ref,
        "us_per_image": _us_per_image_rows(current),
    }
    fo = current.get("fault_overhead")
    if fo:
        row["fault_overhead_ratio"] = fo.get("overhead_ratio")
    do = current.get("durable_overhead")
    if do:
        row["durable_overhead_ratio"] = do.get("overhead_ratio")
    bricks = current.get("bricks")
    if bricks and bricks.get("rows"):
        row["brick_speedups"] = {
            f"{r['method']}/k{r['k']}": r.get("speedup")
            for r in bricks["rows"]
        }
    serving = current.get("serving")
    if serving and serving.get("concurrency"):
        row["serving"] = {
            f"c{c}": {
                "qps_cold": r.get("qps_cold"),
                "speedup_cold": r.get("speedup_cold"),
                "speedup_warm": r.get("speedup_warm"),
                "p95_cold_ms": r.get("p95_cold_ms"),
            }
            for c, r in serving["concurrency"].items()
        }
    robust = current.get("robust_stack")
    if robust:
        row["robust_overhead"] = {
            "clipped_vs_mean": robust.get("overhead_clipped_vs_mean"),
            "median_vs_mean": robust.get("overhead_median_vs_mean"),
        }
    det = current.get("diff_detect")
    if det:
        row["diff_detect"] = {
            "us_per_query": det.get("us_per_query"),
            "recovered": det.get("recovered"),
            "n_injected": det.get("n_injected"),
            "spurious": det.get("spurious"),
        }
    streaming = current.get("streaming")
    if streaming:
        row["streaming"] = {
            k: streaming[k]
            for k in ("t_first_eager_s", "t_first_stream_s",
                      "first_coadd_speedup", "bytes_uploaded_first",
                      "archive_bytes", "oversubscription")
            if k in streaming
        }
    return row


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_coadd.json")
    ap.add_argument("--baseline", default=None,
                    help="base-branch BENCH_coadd.json; missing/absent path "
                         "=> self-baseline (gate passes trivially)")
    ap.add_argument("--threshold", type=float, default=1.5)
    ap.add_argument("--fault-threshold", type=float, default=1.1,
                    help="absolute ceiling on the WindowTracker clean-path "
                         "overhead ratio (tracker-on vs tracker-off)")
    ap.add_argument("--durable-threshold", type=float, default=1.15,
                    help="absolute ceiling on the disk-journal clean-path "
                         "overhead ratio (journal-on vs journal-off)")
    ap.add_argument("--brick-threshold", type=float, default=3.0,
                    help="absolute floor on warm brick-served speedup vs "
                         "the brick-free fresh scan")
    ap.add_argument("--serve-threshold", type=float, default=2.0,
                    help="absolute floor on cache-cold coalesced serving "
                         "throughput vs serial engine.run at the highest "
                         "measured concurrency")
    ap.add_argument("--robust-threshold", type=float, default=2.0,
                    help="per-pass cost ceiling: clipped vs mean, and "
                         "median vs clipped, must each stay under this "
                         "ratio")
    ap.add_argument("--history", default=None,
                    help="base-branch BENCH_trajectory.jsonl to extend")
    ap.add_argument("--trajectory", default="BENCH_trajectory.jsonl")
    ap.add_argument("--sha", default=os.environ.get("GITHUB_SHA", "local"))
    ap.add_argument("--ref", default=os.environ.get("GITHUB_REF", "local"))
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    self_baselined = not (args.baseline and os.path.exists(args.baseline))
    if self_baselined:
        print("perf-gate: no baseline artifact; current run is its own "
              "baseline (first run on this branch?)")
        baseline = current
    else:
        with open(args.baseline) as f:
            baseline = json.load(f)

    regressions, lines = gate(current, baseline, args.threshold)
    print(f"perf-gate: threshold {args.threshold:.2f}x, "
          f"{len(lines)} us/image rows compared:")
    print("\n".join(lines))

    fault_regressions, fault_lines = fault_overhead_gate(
        current, args.fault_threshold)
    print("perf-gate: fault-tracker clean-path overhead:")
    print("\n".join(fault_lines))
    regressions += fault_regressions

    durable_regressions, durable_lines = durable_overhead_gate(
        current, args.durable_threshold)
    print("perf-gate: durable-journal clean-path overhead:")
    print("\n".join(durable_lines))
    regressions += durable_regressions

    brick_regressions, brick_lines = brick_gate(current, args.brick_threshold)
    print("perf-gate: brick-served warm vs cold:")
    print("\n".join(brick_lines))
    regressions += brick_regressions

    serve_regressions, serve_lines = serve_gate(current, args.serve_threshold)
    print("perf-gate: serving throughput under concurrency:")
    print("\n".join(serve_lines))
    regressions += serve_regressions

    robust_regressions, robust_lines = robust_gate(
        current, args.robust_threshold)
    print("perf-gate: robust-estimator overhead + difference detection:")
    print("\n".join(robust_lines))
    regressions += robust_regressions

    # Extend the trajectory: base history (if any) + this run's row.
    if args.history and os.path.exists(args.history) \
            and os.path.abspath(args.history) != os.path.abspath(args.trajectory):
        shutil.copyfile(args.history, args.trajectory)
    with open(args.trajectory, "a") as f:
        f.write(json.dumps(trajectory_row(current, args.sha, args.ref)) + "\n")
    n_rows = sum(1 for _ in open(args.trajectory))
    print(f"perf-gate: trajectory {args.trajectory} now has {n_rows} row(s)")

    if regressions:
        print("perf-gate: FAIL —", len(regressions), "regression(s):")
        for r in regressions:
            print(" ", r)
        return 1
    print("perf-gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
