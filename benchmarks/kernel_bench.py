"""Kernel microbenchmarks: Pallas (interpret) correctness-at-speed + the
XLA-path mapper throughput that the Table-1 numbers are built on."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoaddQuery, SpatialIndex, SurveyConfig, make_survey
from repro.core.mapper import map_batch, query_grid_sky
from repro.core.engine import _coadd_batch  # noqa: F401 (jit cache warm)


def _timeit(fn, *args, repeats=5):
    fn(*args)  # warm/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def bench_mapper_throughput() -> List[str]:
    """Images/second through the (XLA) projection mapper at several sizes."""
    rows = []
    sv = make_survey(SurveyConfig(n_runs=4, n_fields=6, height=32, width=32,
                                  n_sources=100))
    idx = SpatialIndex.build(sv)
    for npix in (64, 128, 256):
        q = CoaddQuery(band="r", ra_bounds=(37.2, 38.0), dec_bounds=(-0.6, 0.4),
                       npix=npix)
        ids = idx.select(q)[:32]
        px = jnp.asarray(np.stack([sv.images[i].pixels for i in ids]))
        wv = jnp.asarray(np.stack([sv.images[i].wcs.to_vector() for i in ids]))
        acc = jnp.ones((len(ids),), jnp.float32)
        gr, gd = map(jnp.asarray, query_grid_sky(q))
        f = jax.jit(lambda px, wv, acc: map_batch(px, wv, acc, gr, gd))
        t = _timeit(f, px, wv, acc)
        rows.append(
            f"kernels/mapper_xla/npix{npix},{t/len(ids)*1e6:.1f},us_per_image"
        )
    return rows


def bench_warp_pallas_interpret() -> List[str]:
    """Pallas warp kernel (interpret mode) vs jnp oracle — parity check.

    Interpret-mode wall time is NOT a TPU speed claim; the derived field is
    the max abs error vs the oracle on the same inputs.
    """
    from repro.kernels.warp import ops as wops
    from repro.kernels.warp import ref as wref

    rows = []
    sv = make_survey(SurveyConfig(n_runs=2, n_fields=4, height=24, width=24,
                                  n_sources=60))
    idx = SpatialIndex.build(sv)
    q = CoaddQuery(band="g", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3), npix=64)
    ids = idx.select(q)[:8]
    px = jnp.asarray(np.stack([sv.images[i].pixels for i in ids]))
    wv = jnp.asarray(np.stack([sv.images[i].wcs.to_vector() for i in ids]))
    acc = jnp.ones((len(ids),), jnp.float32)
    gr, gd = map(jnp.asarray, query_grid_sky(q))
    t_ref, c_ref = wref.coadd_fused_ref(px, wv, acc, gr, gd)
    t0 = time.perf_counter()
    t_k, c_k = wops.coadd_fused(px, wv, acc, gr, gd)
    jax.block_until_ready(t_k)
    dt = time.perf_counter() - t0
    err = float(jnp.abs(t_k - t_ref).max())
    rows.append(f"kernels/coadd_fused_interpret,{dt*1e6:.0f},maxerr={err:.2e}")
    return rows


def bench_flash_attention() -> List[str]:
    from repro.kernels.attention import ops as aops
    from repro.kernels.attention.ref import mha_ref

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    o_k = aops.flash_attention(q, k, v, True, None, 128, 128, True)
    o_r = mha_ref(q, k, v, causal=True)
    err = float(jnp.abs(o_k - o_r).max())
    return [f"kernels/flash_attention_interpret,{0:.0f},maxerr={err:.2e}"]


def bench_ssd() -> List[str]:
    from repro.kernels.ssd import ops as sops
    from repro.kernels.ssd.ref import ssd_batched_ref

    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (1, 256, 2))) * 0.95 + 0.02
    B = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 32))
    C = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 32))
    x = jax.random.normal(jax.random.fold_in(key, 3), (1, 256, 2, 32))
    y_k = sops.ssd(a, B, C, x, chunk=64)
    y_r = ssd_batched_ref(a, B, C, x)
    err = float(jnp.abs(y_k - y_r).max())
    return [f"kernels/ssd_interpret,{0:.0f},maxerr={err:.2e}"]
