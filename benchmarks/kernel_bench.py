"""Kernel microbenchmarks: Pallas (interpret) correctness-at-speed + the
XLA-path mapper throughput that the Table-1 numbers are built on, plus the
device-resident engine's dispatch-count accounting (`BENCH_coadd.json`)."""

from __future__ import annotations

import json
import statistics
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoaddQuery, SpatialIndex, SurveyConfig, make_survey
from repro.core.mapper import map_batch, query_grid_sky
from repro.core.engine import _coadd_batch  # noqa: F401 (jit cache warm)


def _timeit(fn, *args, repeats=5):
    fn(*args)  # warm/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def bench_mapper_throughput() -> List[str]:
    """Images/second through the (XLA) projection mapper at several sizes."""
    rows = []
    sv = make_survey(SurveyConfig(n_runs=4, n_fields=6, height=32, width=32,
                                  n_sources=100))
    idx = SpatialIndex.build(sv)
    for npix in (64, 128, 256):
        q = CoaddQuery(band="r", ra_bounds=(37.2, 38.0), dec_bounds=(-0.6, 0.4),
                       npix=npix)
        ids = idx.select(q)[:32]
        px = jnp.asarray(np.stack([sv.images[i].pixels for i in ids]))
        wv = jnp.asarray(np.stack([sv.images[i].wcs.to_vector() for i in ids]))
        acc = jnp.ones((len(ids),), jnp.float32)
        gr, gd = map(jnp.asarray, query_grid_sky(q))
        f = jax.jit(lambda px, wv, acc: map_batch(px, wv, acc, gr, gd))
        t = _timeit(f, px, wv, acc)
        rows.append(
            f"kernels/mapper_xla/npix{npix},{t/len(ids)*1e6:.1f},us_per_image"
        )
    return rows


def bench_warp_pallas_interpret() -> List[str]:
    """Pallas warp kernel (interpret mode) vs jnp oracle — parity check.

    Interpret-mode wall time is NOT a TPU speed claim; the derived field is
    the max abs error vs the oracle on the same inputs.
    """
    from repro.kernels.warp import ops as wops
    from repro.kernels.warp import ref as wref

    rows = []
    sv = make_survey(SurveyConfig(n_runs=2, n_fields=4, height=24, width=24,
                                  n_sources=60))
    idx = SpatialIndex.build(sv)
    q = CoaddQuery(band="g", ra_bounds=(37.2, 37.8), dec_bounds=(-0.5, 0.3), npix=64)
    ids = idx.select(q)[:8]
    px = jnp.asarray(np.stack([sv.images[i].pixels for i in ids]))
    wv = jnp.asarray(np.stack([sv.images[i].wcs.to_vector() for i in ids]))
    acc = jnp.ones((len(ids),), jnp.float32)
    gr, gd = map(jnp.asarray, query_grid_sky(q))
    t_ref, c_ref = wref.coadd_fused_ref(px, wv, acc, gr, gd)
    t0 = time.perf_counter()
    t_k, c_k = wops.coadd_fused(px, wv, acc, gr, gd)
    jax.block_until_ready(t_k)
    dt = time.perf_counter() - t0
    err = float(jnp.abs(t_k - t_ref).max())
    rows.append(f"kernels/coadd_fused_interpret,{dt*1e6:.0f},maxerr={err:.2e}")
    return rows


def _seed_dispatches(stats, capacity: int) -> int:
    """Dispatch count the seed per-pack loop would have issued (the
    "before" column): one jit call per touched pack, or per gathered
    capacity-chunk on the SQL paths."""
    if stats.method.startswith("sql_"):
        return int(np.ceil(max(stats.files_considered, 1) / capacity))
    return stats.packs_touched


def _best_run(eng, query, method, repeats):
    eng.run(query, method)  # warm the jit cache
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = eng.run(query, method)
        dt = time.perf_counter() - t0
        if best is None or dt < best[0]:
            best = (dt, r)
    return best


def bench_coadd_engine(out_path: str = "BENCH_coadd.json",
                       repeats: int = 3) -> List[str]:
    """All six methods through the one-dispatch engine -> BENCH_coadd.json.

    Records, per method: best us/query and us/image for both the sparse
    (gate-aware gather, default) and dense (masked-discard scan of every
    pack) executors, the gated/scanned/budget pack accounting, and the
    dispatch counts before (seed per-pack loop) and after — the perf
    trajectory the sparse-execution refactor is accountable to.
    """
    from benchmarks.paper_tables import QUERY_LARGE, get_engine
    from repro.core import METHODS

    eng = get_engine()
    eng_dense = get_engine(sparse=False)
    methods: Dict[str, Dict] = {}
    rows = []
    for m in METHODS:
        dt, r = _best_run(eng, QUERY_LARGE, m, repeats)
        dt_dense, r_dense = _best_run(eng_dense, QUERY_LARGE, m, repeats)
        s = r.stats
        cap = eng.dataset("per_file" if m.startswith("raw_fits")
                          else ("unstructured" if "unstructured" in m
                                else "structured")).capacity
        n_img = max(s.files_considered, 1)
        methods[m] = {
            "us_per_query": dt * 1e6,
            "us_per_image": dt * 1e6 / n_img,
            "us_per_query_dense": dt_dense * 1e6,
            "speedup_vs_dense": dt_dense / dt,
            "dispatches_before": _seed_dispatches(s, cap),
            "dispatches_after": s.dispatches,
            "files_considered": s.files_considered,
            "files_contributing": s.files_contributing,
            "packs_touched": s.packs_touched,
            "packs_gated": s.packs_gated,
            "packs_scanned": s.packs_scanned,
            "scan_budget": s.scan_budget,
            "packs_scanned_dense": r_dense.stats.packs_scanned,
            "t_locate_s": s.t_locate_s,
            "t_map_reduce_s": s.t_map_reduce_s,
            "t_map_reduce_dense_s": r_dense.stats.t_map_reduce_s,
        }
        rows.append(
            f"coadd/{m},{dt*1e6/n_img:.1f},"
            f"dispatches={s.dispatches}(was {methods[m]['dispatches_before']});"
            f"scanned={s.packs_scanned}/{r_dense.stats.packs_scanned};"
            f"speedup_vs_dense={dt_dense/dt:.2f}x"
        )
    batched = _bench_batched(eng, repeats=repeats)
    for bs, rec in sorted(batched.items(), key=lambda kv: int(kv[0])):
        rows.append(
            f"coadd/batched/b{bs},{rec['us_per_image']:.1f},"
            f"us_per_query={rec['us_per_query']:.0f};dispatches={rec['dispatches']}"
        )
    sel_rows, selectivity = _bench_selectivity(eng, eng_dense, repeats=repeats)
    rows += sel_rows
    stream_rows, streaming = _bench_streaming(repeats=repeats)
    rows += stream_rows
    psf_rows, psf_matched = _bench_psf_matched(repeats=repeats)
    rows += psf_rows
    fault_rows, fault_overhead = _bench_fault_overhead(repeats=repeats)
    rows += fault_rows
    durable_rows, durable_overhead = _bench_durable_overhead(repeats=repeats)
    rows += durable_rows
    brick_rows, bricks = _bench_bricks(repeats=repeats)
    rows += brick_rows
    serving_rows, serving = _bench_serving(repeats=repeats)
    rows += serving_rows
    robust_rows, robust = _bench_robust(eng, repeats=repeats)
    rows += robust_rows
    detect_rows, diff_detect = _bench_diff_detect(repeats=repeats)
    rows += detect_rows
    payload = {
        "npix": QUERY_LARGE.npix,
        "n_images": eng.dataset("per_file").n_packs,
        "pack_uploads": eng.pack_upload_count,
        "methods": methods,
        "batched": batched,
        "selectivity": selectivity,
        "streaming": streaming,
        "psf_matched_cached": psf_matched,
        "fault_overhead": fault_overhead,
        "durable_overhead": durable_overhead,
        "bricks": bricks,
        "serving": serving,
        "robust_stack": robust,
        "diff_detect": diff_detect,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(f"coadd/json,{0:.0f},wrote={out_path}")
    return rows


def _bench_selectivity(eng, eng_dense, repeats: int = 1,
                       widths=(1.0, 0.5, 0.25, 0.125)) -> tuple:
    """Sparse-vs-dense us/query as query radius (fraction gated) shrinks.

    The paper's Fig. 8 argument on the execute side: shrinking the query
    footprint gates fewer packs, and the sparse path's cost should fall
    with it while the dense scan stays flat.  Uses npix=64 so each budget
    bucket's compile stays cheap; the curve, not the absolute time, is the
    product.
    """
    from benchmarks.paper_tables import QUERY_LARGE
    from repro.core import CoaddQuery

    sweep_methods = ("raw_fits_prefiltered", "structured_seq_prefiltered")
    rows: List[str] = []
    out: List[Dict] = []
    ra0 = QUERY_LARGE.ra_bounds[0]
    full = QUERY_LARGE.ra_bounds[1] - QUERY_LARGE.ra_bounds[0]
    dec0 = QUERY_LARGE.dec_bounds[0]
    dec_full = QUERY_LARGE.dec_bounds[1] - QUERY_LARGE.dec_bounds[0]
    for m in sweep_methods:
        exec_ds, _ = eng.exec_dataset(
            "per_file" if m.startswith("raw_fits") else "structured"
        )
        for wfrac in widths:
            q = CoaddQuery(
                band=QUERY_LARGE.band,
                ra_bounds=(ra0, ra0 + full * wfrac),
                dec_bounds=(dec0, dec0 + dec_full * wfrac),
                npix=64,
            )
            dt_s, r_s = _best_run(eng, q, m, repeats)
            dt_d, _ = _best_run(eng_dense, q, m, repeats)
            frac = r_s.stats.packs_gated / max(exec_ds.n_packs, 1)
            out.append({
                "method": m,
                "width_frac": wfrac,
                "frac_packs_gated": frac,
                "packs_gated": r_s.stats.packs_gated,
                "scan_budget": r_s.stats.scan_budget,
                "us_per_query_sparse": dt_s * 1e6,
                "us_per_query_dense": dt_d * 1e6,
            })
            rows.append(
                f"coadd/selectivity/{m}/w{wfrac},{dt_s*1e6:.0f},"
                f"frac_gated={frac:.3f};dense={dt_d*1e6:.0f}"
            )
    return rows, out


def _bench_streaming(repeats: int = 1, oversubscribe: int = 4) -> tuple:
    """Streaming residency vs eager full-upload (DESIGN.md §6).

    Two rows reproduce the paper's data-flow argument at the device
    boundary: *time-to-first-coadd* (cold residency: the streaming engine
    uploads only the chunks the query gates, the eager engine must land the
    whole archive first) and the *oversubscribed archive* (device budget =
    1/4 of the layout: correctness costs windows and evictions, not
    failure).  A dedicated 48x48-image survey keeps the archive transfer a
    measurable fraction of a query on CPU; jit caches are warmed first and
    cold times are medians of 5, so the rows measure the pipeline, not XLA
    compilation or scheduler noise.  ``bytes_uploaded_first`` is the
    deterministic form of the same claim for the CI gate.
    """
    import statistics

    from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey

    sv = make_survey(SurveyConfig(n_runs=6, n_camcols=6, n_bands=5,
                                  n_fields=10, height=48, width=48,
                                  n_sources=250, seed=82))
    method = "sql_structured"
    # Quarter-deg first query (time-to-first-coadd) + two band-wide 1-deg
    # queries whose combined working set exceeds the budget, so the
    # oversubscribed steady state pays real eviction/re-upload churn.
    q_first = CoaddQuery(band="r", ra_bounds=(37.6, 37.85),
                         dec_bounds=(-0.55, -0.3), npix=64)
    q_wide = CoaddQuery(band="r", ra_bounds=(37.6, 38.6),
                        dec_bounds=(-0.55, 0.45), npix=64)
    q_churn = CoaddQuery(band="g", ra_bounds=(37.6, 38.6),
                         dec_bounds=(-0.55, 0.45), npix=64)
    eager = CoaddEngine(sv, pack_capacity=64)
    exec_ds, _ = eager.exec_dataset("structured")
    archive_bytes = exec_ds.chunk_nbytes(0, exec_ds.n_packs)
    budget = max(archive_bytes // oversubscribe, 1)
    stream = CoaddEngine(sv, pack_capacity=64, device_budget_bytes=budget)
    for eng in (eager, stream):        # warm jit for both program shapes
        eng.run(q_first, method)
        eng.run(q_wide, method)
        eng.run(q_churn, method)

    def cold_one(engine):
        if engine.device_budget_bytes is None:
            engine._device_cache.clear()       # force the full re-upload
        else:
            engine.residency.clear()
        t0 = time.perf_counter()
        r = engine.run(q_first, method)
        return time.perf_counter() - t0, r

    # Interleave the two engines' cold samples so machine-load drift hits
    # both medians equally instead of whichever ran second.
    n_cold = 7
    bytes0 = stream.residency.bytes_uploaded
    ts_eager, ts_stream = [], []
    for _ in range(n_cold):
        ts_eager.append(cold_one(eager)[0])
        dt, r_stream = cold_one(stream)
        ts_stream.append(dt)
    t_eager = statistics.median(ts_eager)
    t_stream = statistics.median(ts_stream)
    bytes_first = (stream.residency.bytes_uploaded - bytes0) // n_cold
    # Oversubscribed steady state: alternating the two band-wide queries
    # cycles a working set larger than the budget, so every switch pays
    # LRU evictions and chunk re-uploads — the price of correctness under
    # oversubscription, never failure.  The eager engine (everything
    # resident) is the churn-free reference.
    def churned(engine, n=max(repeats, 2)):
        best = best_r = None
        for _ in range(n):
            engine.run(q_churn, method)     # evict the r-band working set
            t0 = time.perf_counter()
            r = engine.run(q_wide, method)
            dt = time.perf_counter() - t0
            if best is None or dt < best:
                best, best_r = dt, r
        return best, best_r

    t_eager_wide, _ = churned(eager)
    t_stream_wide, r_wide = churned(stream)
    streaming = {
        "method": method,
        "archive_bytes": archive_bytes,
        "budget_bytes": budget,
        "oversubscription": archive_bytes / budget,
        "t_first_eager_s": t_eager,
        "t_first_stream_s": t_stream,
        "first_coadd_speedup": t_eager / t_stream,
        "bytes_uploaded_first": bytes_first,
        "us_per_query_eager_wide": t_eager_wide * 1e6,
        "us_per_query_stream_wide": t_stream_wide * 1e6,
        "windows_wide": r_wide.stats.windows,
        "chunk_uploads_wide": r_wide.stats.chunk_uploads,
        "evictions_total": stream.residency.evictions,
    }
    rows = [
        f"coadd/streaming/first_coadd,{t_stream*1e6:.0f},"
        f"eager={t_eager*1e6:.0f};speedup={t_eager/t_stream:.2f}x;"
        f"bytes={bytes_first}/{archive_bytes}",
        f"coadd/streaming/oversubscribed_{oversubscribe}x,"
        f"{t_stream_wide*1e6:.0f},"
        f"eager={t_eager_wide*1e6:.0f};windows={r_wide.stats.windows};"
        f"evictions={stream.residency.evictions}",
    ]
    return rows, streaming


def _bench_fault_overhead(repeats: int = 1, oversubscribe: int = 4) -> tuple:
    """Clean-path cost of the window fault tracker (DESIGN.md §8).

    Two identically-budgeted streaming engines run the same warm
    multi-window query: tracker ON (``on_fault="retry"`` — journaled window
    tasks, retry net armed, chunk verification on rebuilds) vs tracker OFF
    (``on_fault="raise"`` — the bare PR 4 loop that aborts on any fault).
    Fault tolerance must be paid for by *faults*, not by every healthy
    query: the ratio is gated <= 1.1x in `perf_gate.py`, and the two
    results must agree bitwise (the tracker changes scheduling, never
    arithmetic).  Samples interleave so machine-load drift hits both
    medians equally.
    """
    import statistics

    from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey

    sv = make_survey(SurveyConfig(n_runs=6, n_camcols=6, n_bands=5,
                                  n_fields=10, height=48, width=48,
                                  n_sources=250, seed=82))
    method = "sql_structured"
    q = CoaddQuery(band="r", ra_bounds=(37.6, 38.6),
                   dec_bounds=(-0.55, 0.45), npix=64)
    probe = CoaddEngine(sv, pack_capacity=64)
    exec_ds, _ = probe.exec_dataset("structured")
    budget = max(exec_ds.chunk_nbytes(0, exec_ds.n_packs) // oversubscribe, 1)

    def mk(policy):
        return CoaddEngine(sv, pack_capacity=64, device_budget_bytes=budget,
                           on_fault=policy)

    tracked, plain = mk("retry"), mk("raise")
    r_on = tracked.run(q, method)       # warm jit + residency for both
    r_off = plain.run(q, method)
    bitwise_equal = bool(
        np.array_equal(r_on.coadd, r_off.coadd)
        and np.array_equal(r_on.depth, r_off.depth)
    )
    n = max(5, repeats)
    ts_on, ts_off = [], []
    for _ in range(n):
        t0 = time.perf_counter()
        r_on = tracked.run(q, method)
        ts_on.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        r_off = plain.run(q, method)
        ts_off.append(time.perf_counter() - t0)
    t_on = statistics.median(ts_on)
    t_off = statistics.median(ts_off)
    n_img = max(r_on.stats.files_considered, 1)
    rec = {
        "method": method,
        "windows": r_on.stats.windows,
        "us_per_query_tracker_on": t_on * 1e6,
        "us_per_query_tracker_off": t_off * 1e6,
        "us_per_image_tracker_on": t_on * 1e6 / n_img,
        "us_per_image_tracker_off": t_off * 1e6 / n_img,
        "overhead_ratio": t_on / t_off,
        "bitwise_equal": bitwise_equal,
        "retries": r_on.stats.retries,          # clean path: must be 0
        "resumed_windows": r_on.stats.resumed_windows,
    }
    rows = [
        f"coadd/fault_overhead,{t_on*1e6/n_img:.1f},"
        f"off={t_off*1e6/n_img:.1f};ratio={t_on/t_off:.3f}x;"
        f"windows={r_on.stats.windows};bitwise={bitwise_equal}"
    ]
    return rows, rec


def _bench_durable_overhead(repeats: int = 1, oversubscribe: int = 4) -> tuple:
    """Clean-path cost of the durable disk journal (DESIGN.md §8).

    Two identically-budgeted streaming engines run the same warm
    multi-window query: journal ON (``journal_dir`` set — every window
    partial writes through an fsynced, checksummed segment, GC'd on
    completion) vs journal OFF (the in-memory default).  Durability must be
    paid for in I/O a healthy query can afford: the ratio is gated
    <= 1.15x absolutely in `perf_gate.py`, and the two results must agree
    bitwise (the journal is a side channel, never an operand).  Samples
    interleave so machine-load drift hits both medians equally.

    Twice the fields of the fault-overhead survey: the journal's cost is a
    fixed few-hundred-us per query plus ~0.3 ms per window commit, so a
    query must scan enough images for the ratio to measure the journal and
    not the price of `mkdir`.
    """
    import shutil
    import statistics
    import tempfile

    from repro.core import CoaddEngine, CoaddQuery, SurveyConfig, make_survey

    sv = make_survey(SurveyConfig(n_runs=6, n_camcols=6, n_bands=5,
                                  n_fields=20, height=48, width=48,
                                  n_sources=250, seed=82))
    method = "sql_structured"
    q = CoaddQuery(band="r", ra_bounds=(37.6, 38.6),
                   dec_bounds=(-0.55, 0.45), npix=64)
    probe = CoaddEngine(sv, pack_capacity=64)
    exec_ds, _ = probe.exec_dataset("structured")
    budget = max(exec_ds.chunk_nbytes(0, exec_ds.n_packs) // oversubscribe, 1)
    jdir = tempfile.mkdtemp(prefix="bench-durable-")
    try:
        durable = CoaddEngine(sv, pack_capacity=64,
                              device_budget_bytes=budget, journal_dir=jdir)
        memory = CoaddEngine(sv, pack_capacity=64,
                             device_budget_bytes=budget)
        r_on = durable.run(q, method)    # warm jit + residency for both
        r_off = memory.run(q, method)
        bitwise_equal = bool(
            np.array_equal(r_on.coadd, r_off.coadd)
            and np.array_equal(r_on.depth, r_off.depth)
        )
        n = max(7, repeats)
        ts_on, ts_off = [], []
        for _ in range(n):
            t0 = time.perf_counter()
            r_on = durable.run(q, method)
            ts_on.append(time.perf_counter() - t0)
            # Completion GC reaps tombs on a background thread; settle it
            # so the next sample (either engine) isn't billed for it.
            durable.journal_store.drain_tombs()
            t0 = time.perf_counter()
            r_off = memory.run(q, method)
            ts_off.append(time.perf_counter() - t0)
        # min, not median: shared-runner noise only ever adds time, and the
        # gate is on the *intrinsic* journal cost, not the machine's mood.
        t_on = min(ts_on)
        t_off = min(ts_off)
        overhead = t_on / t_off
        jobs_left = durable.journal_store.jobs()
    finally:
        shutil.rmtree(jdir, ignore_errors=True)
    n_img = max(r_on.stats.files_considered, 1)
    rec = {
        "method": method,
        "windows": r_on.stats.windows,
        "us_per_query_journal_on": t_on * 1e6,
        "us_per_query_journal_off": t_off * 1e6,
        "us_per_image_journal_on": t_on * 1e6 / n_img,
        "us_per_image_journal_off": t_off * 1e6 / n_img,
        "overhead_ratio": overhead,
        "bitwise_equal": bitwise_equal,
        "jobs_left": len(jobs_left),        # clean exit: must be 0
    }
    rows = [
        f"coadd/durable_overhead,{t_on*1e6/n_img:.1f},"
        f"off={t_off*1e6/n_img:.1f};ratio={overhead:.3f}x;"
        f"windows={r_on.stats.windows};bitwise={bitwise_equal}"
    ]
    return rows, rec


def _bench_bricks(repeats: int = 1) -> tuple:
    """Brick-served warm queries vs the brick-free fresh scan (§9).

    Per prefiltered method: materialize the r-band brick lattice once
    (`materialize_s` is that precompute bill), then time warm
    ``run(use_bricks=True)`` — every tile a device-tier hit, one mosaic
    dispatch — against ``run_window`` (the fresh lattice-window scan the
    mosaic must match bitwise) at three window sizes.  Samples interleave
    so load drift hits both medians equally; `perf_gate.py` requires
    cached >= 3x cold on these rows and bitwise equality on all.
    """
    import statistics

    from repro.core import CoaddEngine, SurveyConfig, make_survey

    sv = make_survey(SurveyConfig(n_runs=6, n_camcols=6, n_bands=5,
                                  n_fields=10, height=24, width=24,
                                  n_sources=250, seed=82))
    methods = ("raw_fits_prefiltered", "structured_seq_prefiltered")
    rows: List[str] = []
    out_rows: List[Dict] = []
    materialize_s = 0.0
    n_bricks = 0
    for m in methods:
        eng = CoaddEngine(sv, pack_capacity=64, brick_deg=0.5, brick_npix=64)
        n_bricks = eng.brick_grid.n_bricks
        t0 = time.perf_counter()
        eng.materialize_bricks(bands=("r",), method=m)
        materialize_s += time.perf_counter() - t0
        for k in (1, 2, 3):
            wq = eng.brick_grid.window_query(1, 1 + k, 1, 1 + k, "r")
            cold = eng.run_window(wq, m)               # warm the fresh jit
            warm = eng.run(wq, m, use_bricks=True)     # compile the mosaic
            bitwise = bool(
                np.array_equal(warm.coadd, cold.coadd)
                and np.array_equal(warm.depth, cold.depth)
            )
            n = max(5, repeats)
            ts_w, ts_c = [], []
            for _ in range(n):
                t0 = time.perf_counter()
                warm = eng.run(wq, m, use_bricks=True)
                ts_w.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                eng.run_window(wq, m)
                ts_c.append(time.perf_counter() - t0)
            t_w = statistics.median(ts_w)
            t_c = statistics.median(ts_c)
            out_rows.append({
                "method": m,
                "k": k,
                "n_bricks": k * k,
                "us_per_query_cached": t_w * 1e6,
                "us_per_query_cold": t_c * 1e6,
                "speedup": t_c / t_w,
                "bricks_hit": warm.stats.bricks_hit,
                "bitwise_equal": bitwise,
            })
            rows.append(
                f"coadd/bricks/{m}/k{k},{t_w*1e6:.0f},"
                f"cold={t_c*1e6:.0f};speedup={t_c/t_w:.2f}x;"
                f"hits={warm.stats.bricks_hit};bitwise={bitwise}"
            )
    rec = {
        "brick_deg": 0.5,
        "brick_npix": 64,
        "n_bricks": n_bricks,
        "materialize_s": materialize_s,
        "rows": out_rows,
    }
    return rows, rec


def _bench_serving(repeats: int = 1,
                   concurrencies=(1, 4, 16)) -> tuple:
    """Queries/sec under concurrency through `CoaddService` (DESIGN.md §10).

    The workload is the multi-tenant repeat traffic the serving layer
    exists for: at each concurrency C, clients draw from a small pool of
    distinct same-layout queries with popularity skew.  Three passes per C:

    * **serial** — the same C requests one at a time through bare
      ``engine.run`` (no batching, no cache): the pre-service baseline.
    * **cold** — a fresh service, empty result cache: wins come from
      coalescing the burst into one vmapped dispatch and singleflight-
      merging identical in-flight requests.
    * **warm** — the identical burst replayed on the same service: result
      cache hits, the Kolosov ingest-once/serve-forever regime.

    The burst is queued before the dispatcher starts (the recorded-burst
    replay pattern), so the coalesce grouping — and therefore which batch
    programs compile during warmup — is deterministic.  `perf_gate.py
    --serve-threshold` requires cold >= 2x serial queries/sec at C=16
    with zero shed.
    """
    import asyncio
    import statistics

    from benchmarks.paper_tables import get_survey
    from repro.core import CoaddEngine, CoaddQuery
    from repro.core.serve import CoaddService

    sv = get_survey()
    eng = CoaddEngine(sv, pack_capacity=64)
    method = "sql_structured"
    pool = []
    for i in range(4):
        lo = 37.6 + 0.18 * i
        pool.append(CoaddQuery(band="r", ra_bounds=(lo, lo + 0.35),
                               dec_bounds=(-0.25, 0.2), npix=64))
    rng = np.random.default_rng(820)
    w = 1.0 / np.arange(1, len(pool) + 1)
    bursts = {
        c: [pool[int(i)] for i in
            rng.choice(len(pool), size=c, p=w / w.sum())]
        for c in concurrencies
    }

    async def burst(svc, queries):
        tasks = [asyncio.ensure_future(svc.submit(q, method))
                 for q in queries]
        # Wait until every request is either queued or already answered
        # (cache hits on warm passes never enqueue), then dispatch.
        while svc.queue_depth + sum(t.done() for t in tasks) < len(queries):
            await asyncio.sleep(0.001)
        async with svc:
            await asyncio.gather(*tasks)

    def service_pass(queries, svc=None):
        svc = svc or CoaddService(eng, method=method, max_queue=64,
                                  max_batch=max(concurrencies))
        t0 = time.perf_counter()
        asyncio.run(burst(svc, queries))
        return svc, time.perf_counter() - t0

    for q in pool:                      # warm the single-program jits
        eng.run(q, method)
    for c, queries in bursts.items():   # warm the batch-program jits
        service_pass(queries)

    rows: List[str] = []
    rec: Dict[str, Dict] = {"pool": len(pool), "npix": 64,
                            "method": method, "concurrency": {}}
    n = max(3, repeats)
    for c, queries in bursts.items():
        ts_serial, ts_cold, ts_warm = [], [], []
        snap = None
        for _ in range(n):
            t0 = time.perf_counter()
            for q in queries:
                eng.run(q, method)
            ts_serial.append(time.perf_counter() - t0)
            svc, dt_cold = service_pass(queries)
            ts_cold.append(dt_cold)
            snap = svc.stats.snapshot()  # cold-pass telemetry only
            _, dt_warm = service_pass(queries, svc=svc)
            ts_warm.append(dt_warm)
            snap_warm = svc.stats.snapshot()  # cumulative incl. warm hits
        t_serial = statistics.median(ts_serial)
        t_cold = statistics.median(ts_cold)
        t_warm = statistics.median(ts_warm)
        entry = {
            "clients": c,
            "qps_serial": c / t_serial,
            "qps_cold": c / t_cold,
            "qps_warm": c / t_warm,
            "speedup_cold": t_serial / t_cold,
            "speedup_warm": t_serial / t_warm,
            "p95_cold_ms": snap["p95_ms"],
            "coalesce_factor": snap["coalesce_factor"],
            "merged_inflight": snap["merged_inflight"],
            "cache_hits": snap_warm["cache_hits"],
            "shed": (snap_warm["shed_queue_full"]
                     + snap_warm["shed_tenant_cap"]),
        }
        rec["concurrency"][str(c)] = entry
        rows.append(
            f"coadd/serving/c{c}/cold,{t_cold*1e6/c:.0f},"
            f"qps={entry['qps_cold']:.1f};serial={entry['qps_serial']:.1f};"
            f"speedup={entry['speedup_cold']:.2f}x;"
            f"coalesce={entry['coalesce_factor']:.1f}"
        )
        rows.append(
            f"coadd/serving/c{c}/warm,{t_warm*1e6/c:.0f},"
            f"qps={entry['qps_warm']:.1f};"
            f"speedup={entry['speedup_warm']:.2f}x;"
            f"cache_hits={entry['cache_hits']}"
        )
    return rows, rec


def _bench_psf_matched(repeats: int = 1) -> tuple:
    """Matched-pixel residency cache vs per-dispatch re-convolution (§7).

    Both engines homogenize to the same measured-PSF target through the XLA
    map path; the *uncached* one re-applies the (query-independent) 2-D
    matching convolution inside every dispatch, the *cached* one convolved
    once at residency time and scans matched pixels.  The claim the rows
    carry: cached per-query map time below uncached, with ZERO extra
    uploads or matched-pixel rebuilds on repeat queries — results are
    bitwise-identical (tests pin that), so this is pure time-for-memory.
    """
    from benchmarks.paper_tables import QUERY_LARGE, get_survey
    from repro.core import CoaddEngine

    sv = get_survey()
    # Above the survey's widest measured seeing (~1.6 sigma Gaussian-eq,
    # ~2.1 second-moment for Moffat wings): every slot genuinely widens,
    # none clamps.
    target = 2.4
    method = "sql_structured"
    cached = CoaddEngine(sv, pack_capacity=64, match_psf_sigma=target)
    uncached = CoaddEngine(sv, pack_capacity=64, match_psf_sigma=target,
                           matched_pixel_cache=False)
    # Warm jit caches AND the matched-pixel residency entry.
    cached.run(QUERY_LARGE, method)
    uncached.run(QUERY_LARGE, method)
    uploads0 = cached.pack_upload_count
    builds0 = cached.matched_builds
    dt_c, r_c = _best_run(cached, QUERY_LARGE, method, max(repeats, 2))
    dt_u, _ = _best_run(uncached, QUERY_LARGE, method, max(repeats, 2))
    repeat_uploads = cached.pack_upload_count - uploads0
    repeat_builds = cached.matched_builds - builds0
    n_img = max(r_c.stats.files_considered, 1)
    psf_matched = {
        "method": method,
        "psf_target": target,
        "us_per_query_cached": dt_c * 1e6,
        "us_per_query_uncached": dt_u * 1e6,
        "us_per_image_cached": dt_c * 1e6 / n_img,
        "speedup_vs_uncached": dt_u / dt_c,
        "repeat_uploads": repeat_uploads,
        "repeat_matched_builds": repeat_builds,
        "matched_cache_bytes": int(
            cached.device_dataset("structured").pixels.nbytes
        ),
        # True eager footprint: raw resident layout + matched copy + bank.
        "peak_resident_bytes": r_c.stats.peak_resident_bytes,
    }
    rows = [
        f"coadd/psf_matched_cached,{dt_c*1e6:.0f},"
        f"uncached={dt_u*1e6:.0f};speedup={dt_u/dt_c:.2f}x;"
        f"repeat_uploads={repeat_uploads}"
    ]
    return rows, psf_matched


def _bench_robust(eng, repeats: int = 3) -> tuple:
    """Robust-estimator overhead vs the plain mean (DESIGN.md §11).

    The clipped mean re-scans the gated samples once with fixed clip
    operands (2 passes total), the two-round median adds a binapprox
    histogram pass (3 total) — so the honest cost model is a small
    multiple of the mean's scan time.  Trials are interleaved
    (mean/clipped/median round-robin) and the reported time is the
    min-of-trials — the same best-run statistic `_best_run` uses for the
    method rows: scheduler noise only ever adds time, so the min is the
    estimator's actual cost and the ratio of mins is stable under load
    drift; the perf gate holds the per-pass ratios under
    --robust-threshold.
    """
    from benchmarks.paper_tables import QUERY_LARGE

    fns = {
        "mean": lambda: eng.run(QUERY_LARGE, "sql_structured"),
        "clipped": lambda: eng.run(QUERY_LARGE, "sql_structured",
                                   reduce="clipped"),
        "median": lambda: eng.run(QUERY_LARGE, "sql_structured",
                                  reduce="median"),
    }
    times: Dict[str, List[float]] = {k: [] for k in fns}
    for fn in fns.values():
        fn()  # warm every jit cache before any clock starts
    # Min over >= 5 interleaved trials: the gate rides on the ratio of
    # these, so buy stability — the runs are ~0.1s each.
    for _ in range(max(repeats, 5)):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            times[k].append(time.perf_counter() - t0)
    med = {k: min(v) for k, v in times.items()}
    r = eng.run(QUERY_LARGE, "sql_structured", reduce="clipped")
    n_img = max(r.stats.files_considered, 1)
    robust = {
        "method": "sql_structured",
        "us_per_query_mean": med["mean"] * 1e6,
        "us_per_query_clipped": med["clipped"] * 1e6,
        "us_per_query_median": med["median"] * 1e6,
        "us_per_image_clipped": med["clipped"] * 1e6 / n_img,
        "us_per_image_median": med["median"] * 1e6 / n_img,
        "overhead_clipped_vs_mean": med["clipped"] / med["mean"],
        "overhead_median_vs_mean": med["median"] / med["mean"],
        "reduce_passes_clipped": r.stats.reduce_passes,
        "clip_k": eng.clip_k,
        "median_bins": eng.median_bins,
    }
    rows = [
        f"coadd/robust_stack,{med['clipped']*1e6/n_img:.1f},"
        f"mean={med['mean']*1e6:.0f}us;clipped={med['clipped']*1e6:.0f}us"
        f"(x{robust['overhead_clipped_vs_mean']:.2f});"
        f"median={med['median']*1e6:.0f}us"
        f"(x{robust['overhead_median_vs_mean']:.2f})"
    ]
    return rows, robust


def _bench_diff_detect(repeats: int = 3) -> tuple:
    """Difference imaging + source detection as one timed workload (§11).

    Builds its own survey (transient injection mutates pixels in place —
    the shared benchmark survey must stay pristine), PSF-homogenizes both
    sides, serves the template from materialized bricks, and times the
    epoch-minus-template difference plus the on-device detection.  The
    recovered/spurious counts ride along so a silently broken detector
    can't keep posting good times.
    """
    from repro.core import (
        CoaddEngine, CoaddQuery, SurveyConfig, detect_sources,
        difference_image, inject_transients, make_survey, match_detections,
    )

    sv = make_survey(SurveyConfig(n_runs=3, n_fields=5, n_sources=100,
                                  height=20, width=20))
    query = CoaddQuery(band="r", ra_bounds=(37.3, 37.9),
                       dec_bounds=(-0.5, 0.3), npix=48)
    truths = inject_transients(sv, query, n=8, flux=400.0, seed=7)
    eng = CoaddEngine(sv, pack_capacity=16, match_psf_sigma=2.0)

    def drill():
        diff, da, db = difference_image(eng, query, reduce="clipped")
        return detect_sources(diff, da, db, nsigma=5.0), diff, da, db

    cat, diff, da, db = drill()  # warm jits + materialize template bricks
    ts = []
    for _ in range(max(repeats, 3)):
        t0 = time.perf_counter()
        cat, diff, da, db = drill()
        ts.append(time.perf_counter() - t0)
    dt = statistics.median(ts)
    recovered, spurious = match_detections(cat, query, truths)
    n_img = sum(1 for im in sv.images if im.band == query.band)
    diff_detect = {
        "us_per_query": dt * 1e6,
        "us_per_image": dt * 1e6 / max(n_img, 1),
        "n_injected": int(len(truths)),
        "recovered": recovered,
        "spurious": spurious,
        "detections": len(cat),
        "nsigma": 5.0,
    }
    rows = [
        f"coadd/diff_detect,{dt*1e6/max(n_img,1):.1f},"
        f"us_per_query={dt*1e6:.0f};recovered={recovered}/{len(truths)};"
        f"spurious={spurious}"
    ]
    return rows, diff_detect


def _bench_batched(eng, repeats: int = 3,
                   batch_sizes=(1, 2, 4, 8)) -> Dict[str, Dict]:
    """us/image of `run_batch` per batch size (the paper's Fig. 5 shape).

    Each batch stacks K distinct sql_structured queries (RA-shifted copies of
    the large query) into ONE vmapped dispatch; amortization shows up as
    us/image falling with K while dispatches stay at 1.
    """
    from repro.core import CoaddQuery
    from benchmarks.paper_tables import QUERY_LARGE

    out: Dict[str, Dict] = {}
    for bs in batch_sizes:
        qs = [
            CoaddQuery(
                band=QUERY_LARGE.band,
                ra_bounds=(QUERY_LARGE.ra_bounds[0] - 0.05 * i,
                           QUERY_LARGE.ra_bounds[1] - 0.05 * i),
                dec_bounds=QUERY_LARGE.dec_bounds,
                npix=QUERY_LARGE.npix,
            )
            for i in range(bs)
        ]
        eng.run_batch(qs, "sql_structured")  # warm the jit cache per (bs,)
        best, best_res = None, None
        for _ in range(repeats):
            before = eng.dispatch_count
            t0 = time.perf_counter()
            res = eng.run_batch(qs, "sql_structured")
            dt = time.perf_counter() - t0
            dispatches = eng.dispatch_count - before
            if best is None or dt < best:
                best, best_res = dt, (res, dispatches)
        res, dispatches = best_res
        n_img = max(sum(r.stats.files_considered for r in res), 1)
        out[str(bs)] = {
            "us_per_query": best * 1e6 / bs,
            "us_per_image": best * 1e6 / n_img,
            "dispatches": dispatches,
            "files_considered": n_img,
        }
    return out


def bench_flash_attention() -> List[str]:
    from repro.kernels.attention import ops as aops
    from repro.kernels.attention.ref import mha_ref

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    o_k = aops.flash_attention(q, k, v, True, None, 128, 128, True)
    o_r = mha_ref(q, k, v, causal=True)
    err = float(jnp.abs(o_k - o_r).max())
    return [f"kernels/flash_attention_interpret,{0:.0f},maxerr={err:.2e}"]


def bench_ssd() -> List[str]:
    from repro.kernels.ssd import ops as sops
    from repro.kernels.ssd.ref import ssd_batched_ref

    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (1, 256, 2))) * 0.95 + 0.02
    B = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 32))
    C = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 32))
    x = jax.random.normal(jax.random.fold_in(key, 3), (1, 256, 2, 32))
    y_k = sops.ssd(a, B, C, x, chunk=64)
    y_r = ssd_batched_ref(a, B, C, x)
    err = float(jnp.abs(y_k - y_r).max())
    return [f"kernels/ssd_interpret,{0:.0f},maxerr={err:.2e}"]
