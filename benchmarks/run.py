"""Benchmark entrypoint. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes ``BENCH_coadd.json``
(per-method us/image + before/after dispatch counts for the device-resident
coadd engine).

  python -m benchmarks.run             # everything
  python -m benchmarks.run --fast      # skip the slow Table-1 timing loops
  python -m benchmarks.run --quick     # CI smoke: coadd engine report only
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke lane: only the coadd engine report "
                         "(BENCH_coadd.json incl. batched rows, the "
                         "sparse-vs-dense selectivity sweep, and the "
                         "serving queries/sec-under-concurrency rows), "
                         "one repeat")
    ap.add_argument("--coadd-json", default="BENCH_coadd.json",
                    help="where to write the coadd engine dispatch/latency report")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_tables

    t0 = time.perf_counter()
    rows = ["name,us_per_call,derived"]
    if args.quick:
        rows += kernel_bench.bench_coadd_engine(
            out_path=args.coadd_json, repeats=1
        )
        print("\n".join(rows))
        print(f"# total_bench_wall_s={time.perf_counter()-t0:.1f}", file=sys.stderr)
        return
    rows += paper_tables.bench_table2()
    rows += paper_tables.bench_consistency()
    rows += paper_tables.bench_fig8_breakdown()
    if not args.fast:
        rows += paper_tables.bench_table1()
    # Always write the dispatch-count report (it's the PR-over-PR perf
    # trajectory), but keep --fast fast: one timed repeat instead of three.
    rows += kernel_bench.bench_coadd_engine(
        out_path=args.coadd_json, repeats=1 if args.fast else 3
    )
    rows += kernel_bench.bench_mapper_throughput()
    rows += kernel_bench.bench_warp_pallas_interpret()
    rows += kernel_bench.bench_flash_attention()
    rows += kernel_bench.bench_ssd()
    print("\n".join(rows))
    print(f"# total_bench_wall_s={time.perf_counter()-t0:.1f}", file=sys.stderr)


if __name__ == "__main__":
    main()
